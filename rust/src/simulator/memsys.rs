//! Transaction-level co-simulation of the Fig 1 deployment: DRAM channels
//! serving 64-byte bursts of compressed streams to a replicated decoder
//! array that feeds the accelerator's on-chip buffers.
//!
//! The analytical model in [`super::accelerator`] assumes perfect overlap;
//! this event-driven model resolves the actual interleaving — DRAM busy
//! time per channel, decoder pipeline occupancy, and the backpressure
//! between them — so the engine-count and burst-size design choices can be
//! ablated (paper §V-B sizes 64 engines against a dual-channel DDR4-3200
//! interface; this model shows where fewer engines start to throttle the
//! memory system).

/// One decode job: a substream of `values` values stored at
/// `bits_per_value` compressed bits (fractional — the measured stream
/// rate), resident on DRAM channel `channel`.
#[derive(Debug, Clone, Copy)]
pub struct Substream {
    pub values: u64,
    pub bits_per_value: f64,
    pub channel: usize,
}

/// Configuration of the transaction-level model.
#[derive(Debug, Clone, Copy)]
pub struct MemSysConfig {
    /// DRAM channels.
    pub channels: usize,
    /// Sustained bytes per engine-clock cycle per channel (DDR4-3200 x64 at
    /// a 1 GHz engine clock: 25.6 B/cycle × utilization).
    pub channel_bytes_per_cycle: f64,
    /// Burst (transaction) size in bytes.
    pub burst_bytes: u64,
    /// Number of decoder engines.
    pub engines: usize,
    /// Pipeline fill latency per engine, cycles.
    pub pipeline_fill: u64,
    /// Values per cycle per engine in steady state.
    pub values_per_cycle: f64,
}

impl MemSysConfig {
    /// The paper's deployment: 64 engines, 2 channels, 64 B bursts, 1 GHz.
    pub fn paper() -> Self {
        Self {
            channels: 2,
            channel_bytes_per_cycle: 25.6 * 0.9,
            burst_bytes: 64,
            engines: 64,
            pipeline_fill: 3,
            values_per_cycle: 1.0,
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemSysResult {
    /// Makespan in engine cycles.
    pub cycles: u64,
    /// Total values decoded.
    pub values: u64,
    /// Fraction of cycles each channel was busy (mean over channels).
    pub channel_utilization: f64,
    /// Fraction of engine-cycles doing useful decode work.
    pub engine_utilization: f64,
    /// Cycles engines spent stalled waiting for DRAM bursts.
    pub engine_stall_cycles: u64,
}

impl MemSysResult {
    /// Effective decoded-value throughput, values/cycle.
    pub fn throughput(&self) -> f64 {
        self.values as f64 / self.cycles.max(1) as f64
    }
}

/// Run the transaction-level simulation.
///
/// Event-driven model: substreams are assigned round-robin to engines;
/// each engine processes its queue sequentially, double-buffering bursts
/// (the request for burst *k+1* issues when decode of burst *k* starts).
/// A global event loop orders burst requests across engines in time, so
/// channels serve them FCFS by actual request time; a channel clock
/// (`free_at`) serializes its bursts. An engine stalls only when its
/// channel is the bottleneck.
pub fn simulate(cfg: &MemSysConfig, substreams: &[Substream]) -> MemSysResult {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    assert!(cfg.channels >= 1 && cfg.engines >= 1);
    let burst_cycles = (cfg.burst_bytes as f64 / cfg.channel_bytes_per_cycle).max(1e-9);

    // Per-engine substream queues.
    let mut queues: Vec<Vec<usize>> = vec![Vec::new(); cfg.engines];
    for (si, _) in substreams.iter().enumerate() {
        queues[si % cfg.engines].push(si);
    }

    /// Engine progress through its queue.
    struct Eng {
        queue_pos: usize,
        bursts_left: u64,
        decode_cycles: f64,
        channel: usize,
        decode_ready: f64,
    }
    let mut engines: Vec<Eng> = Vec::with_capacity(cfg.engines);
    // Event heap: (next burst-request time in fixed-point, engine id).
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let fx = |t: f64| (t * 1024.0) as u64; // stable ordering key

    let mut total_values = 0u64;
    let stream_params = |s: &Substream| {
        let total_bits = s.values as f64 * s.bits_per_value;
        let bursts = ((total_bits / 8.0) / cfg.burst_bytes as f64).ceil().max(1.0) as u64;
        let decode_cycles = s.values as f64 / bursts as f64 / cfg.values_per_cycle;
        (bursts, decode_cycles)
    };
    for (e, q) in queues.iter().enumerate() {
        if let Some(&si) = q.first() {
            let s = &substreams[si];
            let (bursts, decode_cycles) = stream_params(s);
            let start = cfg.pipeline_fill as f64;
            engines.push(Eng {
                queue_pos: 0,
                bursts_left: bursts,
                decode_cycles,
                channel: s.channel % cfg.channels,
                decode_ready: start,
            });
            heap.push(Reverse((fx(start), e)));
        } else {
            engines.push(Eng {
                queue_pos: 0,
                bursts_left: 0,
                decode_cycles: 0.0,
                channel: 0,
                decode_ready: 0.0,
            });
        }
    }
    for s in substreams {
        total_values += s.values;
    }

    let mut channel_free = vec![0f64; cfg.channels];
    let mut channel_busy = vec![0f64; cfg.channels];
    let mut engine_busy = vec![0f64; cfg.engines];
    let mut engine_stall = 0f64;
    let mut makespan = 0f64;

    while let Some(Reverse((req_fx, e))) = heap.pop() {
        let req = req_fx as f64 / 1024.0;
        let (ch, decode_cycles) = (engines[e].channel, engines[e].decode_cycles);
        // Serve the burst.
        let fetch_start = channel_free[ch].max(req);
        let fetch_done = fetch_start + burst_cycles;
        channel_free[ch] = fetch_done;
        channel_busy[ch] += burst_cycles;
        // Decode starts when data arrived and previous decode finished.
        let start = fetch_done.max(engines[e].decode_ready);
        engine_stall += (start - engines[e].decode_ready).max(0.0);
        engines[e].decode_ready = start + decode_cycles;
        engine_busy[e] += decode_cycles;
        makespan = makespan.max(engines[e].decode_ready);
        engines[e].bursts_left -= 1;

        if engines[e].bursts_left > 0 {
            // Double buffering: next request when this decode starts.
            heap.push(Reverse((fx(start), e)));
        } else {
            // Advance to the next substream in this engine's queue.
            engines[e].queue_pos += 1;
            if let Some(&si) = queues[e].get(engines[e].queue_pos) {
                let s = &substreams[si];
                let (bursts, decode_cycles) = stream_params(s);
                engines[e].bursts_left = bursts;
                engines[e].decode_cycles = decode_cycles;
                engines[e].channel = s.channel % cfg.channels;
                let next = engines[e].decode_ready + cfg.pipeline_fill as f64;
                engines[e].decode_ready = next;
                heap.push(Reverse((fx(next), e)));
            }
        }
    }
    let cycles = makespan.ceil() as u64;
    let channel_utilization = channel_busy.iter().sum::<f64>()
        / (cfg.channels as f64 * makespan.max(1e-9));
    let engine_utilization =
        engine_busy.iter().sum::<f64>() / (cfg.engines as f64 * makespan.max(1e-9));
    MemSysResult {
        cycles,
        values: total_values,
        channel_utilization,
        engine_utilization,
        engine_stall_cycles: engine_stall.ceil() as u64,
    }
}

/// Convenience: a tensor of `values` values at `bits_per_value`, split
/// evenly into `n` substreams alternating across channels.
pub fn even_substreams(values: u64, bits_per_value: f64, n: usize) -> Vec<Substream> {
    let per = values / n as u64;
    (0..n)
        .map(|i| Substream {
            values: if i == n - 1 { values - per * (n as u64 - 1) } else { per },
            bits_per_value,
            channel: i % 2,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_dram_bound_at_full_replication() {
        // 64 engines decode 64 values/cycle = 64 B/cycle of *decoded* data;
        // 2 channels deliver 46 B/cycle of *compressed* data. At 8 bits/
        // value compressed (no compression), DRAM is the bottleneck.
        let cfg = MemSysConfig::paper();
        let r = simulate(&cfg, &even_substreams(64_000_000, 8.0, 64));
        assert!(r.channel_utilization > 0.95, "{r:?}");
        assert!(r.engine_utilization < 0.95);
    }

    #[test]
    fn compression_amplifies_bandwidth_until_engines_cap() {
        // At 4 bits/value DRAM could feed 2× the values/cycle, but the 64
        // engines cap aggregate decode at 64 values/cycle — so the speedup
        // is min(2.0, 64 / 46.08) ≈ 1.39. (This is exactly the §V-B sizing
        // trade the event model exists to expose; with 128 engines the
        // full 2× materializes.)
        let cfg = MemSysConfig::paper();
        let raw = simulate(&cfg, &even_substreams(16_000_000, 8.0, 64));
        let comp = simulate(&cfg, &even_substreams(16_000_000, 4.0, 64));
        let speedup = raw.cycles as f64 / comp.cycles as f64;
        let cap = 64.0 / (2.0 * 25.6 * 0.9 / 1.0);
        assert!((speedup - cap).abs() < 0.1, "speedup {speedup}, cap {cap}");

        let wide = MemSysConfig { engines: 128, ..cfg };
        let raw_w = simulate(&wide, &even_substreams(16_000_000, 8.0, 128));
        let comp_w = simulate(&wide, &even_substreams(16_000_000, 4.0, 128));
        let speedup_w = raw_w.cycles as f64 / comp_w.cycles as f64;
        assert!((speedup_w - 2.0).abs() < 0.15, "wide speedup {speedup_w}");
    }

    #[test]
    fn too_few_engines_throttle_the_channels() {
        // With 4 engines the decode rate (4 values/cycle = 4 B/cycle)
        // cannot keep up with 46 B/cycle of DRAM: engines saturate, DRAM
        // idles.
        let cfg = MemSysConfig { engines: 4, ..MemSysConfig::paper() };
        let r = simulate(&cfg, &even_substreams(4_000_000, 8.0, 4));
        assert!(r.engine_utilization > 0.9, "{r:?}");
        assert!(r.channel_utilization < 0.5, "{r:?}");
    }

    #[test]
    fn engine_count_sweep_is_monotone() {
        let mut last = u64::MAX;
        for engines in [1usize, 4, 16, 64] {
            let cfg = MemSysConfig { engines, ..MemSysConfig::paper() };
            let r = simulate(&cfg, &even_substreams(1_000_000, 6.0, engines.max(1)));
            assert!(r.cycles <= last, "{engines} engines: {} > {last}", r.cycles);
            last = r.cycles;
        }
    }

    #[test]
    fn value_conservation_and_sane_utilizations() {
        let cfg = MemSysConfig::paper();
        let subs = even_substreams(1_234_567, 5.3, 17);
        let total: u64 = subs.iter().map(|s| s.values).sum();
        assert_eq!(total, 1_234_567);
        let r = simulate(&cfg, &subs);
        assert_eq!(r.values, 1_234_567);
        assert!(r.channel_utilization <= 1.0 + 1e-9);
        assert!(r.engine_utilization <= 1.0 + 1e-9);
    }
}
