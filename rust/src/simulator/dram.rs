//! DDR4 off-chip memory model (timing + power), structured after Micron's
//! DDR4 power calculator which the paper uses (§VII "Hardware and Energy
//! Modeling"): per-access energy is derived from IDD currents and the
//! command mix, background power from the idle/active standby currents.
//!
//! Configuration matches the paper: dual-channel DDR4-3200, 8 GB
//! (Fig 5/6 study) with x64 channels.


/// DDR4 device/channel configuration and electrical parameters.
///
/// Current values are representative of Micron 8 Gb DDR4-3200 datasheet
/// figures (IDD in mA, VDD in volts). The energy model follows the
/// structure of the Micron power calculator: activate/precharge energy per
/// row cycle, read/write burst energy per column access, I/O + termination
/// per bit, and background standby power.
#[derive(Debug, Clone, Copy)]
pub struct DramConfig {
    /// Number of independent channels (paper: 2).
    pub channels: u32,
    /// Data bus width per channel in bits (64 for commodity DIMMs).
    pub bus_bits: u32,
    /// Data rate in MT/s (3200 for DDR4-3200).
    pub mt_per_s: u64,
    /// DRAM core clock in MHz (= MT/s / 2).
    pub tck_mhz: u64,
    /// Supply voltage.
    pub vdd: f64,
    /// Active-precharge current (IDD0), mA.
    pub idd0_ma: f64,
    /// Precharge standby current (IDD2N), mA.
    pub idd2n_ma: f64,
    /// Active standby current (IDD3N), mA.
    pub idd3n_ma: f64,
    /// Read burst current (IDD4R), mA.
    pub idd4r_ma: f64,
    /// Write burst current (IDD4W), mA.
    pub idd4w_ma: f64,
    /// Row cycle time tRC in ns.
    pub trc_ns: f64,
    /// Row size in bytes (columns × bus width) — determines how many bytes
    /// one activate can serve under streaming access.
    pub row_bytes: u64,
    /// I/O + ODT energy per transferred bit, pJ (driver + termination).
    pub io_pj_per_bit: f64,
    /// Fraction of accesses that hit an already-open row for *streaming*
    /// traffic (APack reads/writes both streams sequentially, §IV).
    pub streaming_row_hit: f64,
}

impl DramConfig {
    /// The paper's dual-channel 8 GB DDR4-3200 configuration.
    pub fn ddr4_3200_dual() -> Self {
        Self {
            channels: 2,
            bus_bits: 64,
            mt_per_s: 3200,
            tck_mhz: 1600,
            vdd: 1.2,
            idd0_ma: 58.0,
            idd2n_ma: 37.0,
            idd3n_ma: 52.0,
            idd4r_ma: 170.0,
            idd4w_ma: 160.0,
            trc_ns: 45.75,
            row_bytes: 8192,
            io_pj_per_bit: 4.5,
            streaming_row_hit: 0.95,
        }
    }

    /// Peak bandwidth across all channels, bytes/s.
    pub fn peak_bandwidth(&self) -> f64 {
        self.channels as f64 * self.mt_per_s as f64 * 1e6 * (self.bus_bits as f64 / 8.0)
    }
}

/// Energy/power results for a traffic episode.
#[derive(Debug, Clone, Copy, Default)]
pub struct DramEnergy {
    /// Activate/precharge energy (J).
    pub act_pre_j: f64,
    /// Read/write burst core energy (J).
    pub burst_j: f64,
    /// I/O and termination energy (J).
    pub io_j: f64,
    /// Background (standby) energy over the episode duration (J).
    pub background_j: f64,
}

impl DramEnergy {
    /// Total energy in joules.
    pub fn total_j(&self) -> f64 {
        self.act_pre_j + self.burst_j + self.io_j + self.background_j
    }
}

/// The DDR4 power model.
#[derive(Debug, Clone, Copy)]
pub struct DramPowerModel {
    pub cfg: DramConfig,
}

impl DramPowerModel {
    pub fn new(cfg: DramConfig) -> Self {
        Self { cfg }
    }

    /// Energy per activate+precharge cycle (Micron: `(IDD0 − IDD3N·tRAS/tRC
    /// − IDD2N·tRP/tRC)·VDD·tRC` ≈ the row overhead; we fold tRAS/tRP into
    /// a single net overhead term).
    fn act_pre_energy_j(&self) -> f64 {
        let c = &self.cfg;
        let net_ma = c.idd0_ma - 0.6 * c.idd3n_ma - 0.4 * c.idd2n_ma;
        net_ma * 1e-3 * c.vdd * c.trc_ns * 1e-9
    }

    /// Core burst energy per byte (read or write).
    fn burst_energy_j_per_byte(&self, write: bool) -> f64 {
        let c = &self.cfg;
        let idd4 = if write { c.idd4w_ma } else { c.idd4r_ma };
        // Burst current above active standby, for the time one byte
        // occupies the bus on one channel.
        let ns_per_byte = 8.0 / (c.bus_bits as f64 * c.mt_per_s as f64 * 1e-3); // ns
        (idd4 - c.idd3n_ma) * 1e-3 * c.vdd * ns_per_byte * 1e-9
    }

    /// Energy to move `read_bytes` + `write_bytes` with streaming access
    /// over an episode of `duration_s` seconds (for background power).
    pub fn traffic_energy(&self, read_bytes: u64, write_bytes: u64, duration_s: f64) -> DramEnergy {
        let c = &self.cfg;
        let total_bytes = read_bytes + write_bytes;
        // Row activations: misses on streaming-fraction of accesses.
        let rows = (total_bytes as f64 / c.row_bytes as f64) / c.streaming_row_hit.max(1e-9);
        let act_pre_j = rows * self.act_pre_energy_j();
        let burst_j = read_bytes as f64 * self.burst_energy_j_per_byte(false)
            + write_bytes as f64 * self.burst_energy_j_per_byte(true);
        let io_j = total_bytes as f64 * 8.0 * c.io_pj_per_bit * 1e-12;
        let background_j = self.background_power_w() * duration_s;
        DramEnergy { act_pre_j, burst_j, io_j, background_j }
    }

    /// Standby (background) power of all channels, watts.
    pub fn background_power_w(&self) -> f64 {
        let c = &self.cfg;
        // Mix of active and precharge standby across devices; a x64 channel
        // of x8 devices has 8 devices.
        let devices = (c.bus_bits / 8) as f64 * c.channels as f64;
        0.5 * (c.idd3n_ma + c.idd2n_ma) * 1e-3 * c.vdd * devices
    }

    /// Average power when streaming at `utilization` of peak bandwidth
    /// (used for the paper's "4.7% of DDR4 power at 90% utilization"
    /// comparison).
    pub fn power_at_utilization(&self, utilization: f64) -> f64 {
        let bytes_per_s = self.cfg.peak_bandwidth() * utilization;
        // Half reads half writes, 1 second episode.
        let e = self.traffic_energy(
            (bytes_per_s / 2.0) as u64,
            (bytes_per_s / 2.0) as u64,
            1.0,
        );
        e.total_j()
    }

    /// Time to transfer `bytes` at `utilization` of peak bandwidth.
    pub fn transfer_time_s(&self, bytes: u64, utilization: f64) -> f64 {
        bytes as f64 / (self.cfg.peak_bandwidth() * utilization)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DramPowerModel {
        DramPowerModel::new(DramConfig::ddr4_3200_dual())
    }

    #[test]
    fn peak_bandwidth_is_51_2_gbs() {
        let bw = DramConfig::ddr4_3200_dual().peak_bandwidth();
        assert!((bw / 51.2e9 - 1.0).abs() < 1e-9, "{bw}");
    }

    #[test]
    fn energy_scales_with_traffic() {
        let m = model();
        let e1 = m.traffic_energy(1 << 30, 0, 0.0).total_j();
        let e2 = m.traffic_energy(2 << 30, 0, 0.0).total_j();
        assert!((e2 / e1 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn per_bit_energy_in_plausible_ddr4_range() {
        // Literature: DDR4 access energy ≈ 10–40 pJ/bit at the device
        // (excluding background/controller).
        let m = model();
        let bytes = 1u64 << 30;
        let e = m.traffic_energy(bytes / 2, bytes / 2, 0.0);
        let pj_per_bit = e.total_j() / (bytes as f64 * 8.0) * 1e12;
        assert!(
            (5.0..40.0).contains(&pj_per_bit),
            "pJ/bit = {pj_per_bit:.2}"
        );
    }

    #[test]
    fn power_at_90pct_utilization_order_of_watts() {
        // A dual-channel DDR4-3200 system at 90% streaming utilization
        // draws a few watts — the denominator of the paper's 4.7% overhead
        // claim (179.2 mW / P_dram ≈ 4.7% → P_dram ≈ 3.8 W).
        let p = model().power_at_utilization(0.9);
        assert!((1.5..8.0).contains(&p), "P = {p:.2} W");
    }

    #[test]
    fn writes_cost_at_least_comparable_to_reads() {
        let m = model();
        let er = m.traffic_energy(1 << 28, 0, 0.0).total_j();
        let ew = m.traffic_energy(0, 1 << 28, 0.0).total_j();
        assert!((ew / er - 1.0).abs() < 0.25);
    }

    #[test]
    fn transfer_time_inverse_of_bandwidth() {
        let m = model();
        let t = m.transfer_time_s(51_200_000_000 / 10, 1.0);
        assert!((t - 0.1).abs() < 1e-9);
    }
}
