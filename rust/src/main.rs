//! `apack-repro` CLI: compress/decompress tensors, pack and serve
//! APackStore files, print the paper's tables and figures, and run the
//! end-to-end PJRT inference demo.
//!
//! (Argument parsing is hand-rolled — this build environment has no clap;
//! errors are plain `Box<dyn Error>` for the same reason.)

use std::error::Error;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::{Duration, Instant};

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::apack::DecodeKernel;
use apack_repro::coordinator::{Coordinator, PartitionPolicy, ShardedContainer};
use apack_repro::eval::{self, CompressionStudy};
use apack_repro::models::zoo::{all_models, model_by_name, ModelConfig};
use apack_repro::obs;
use apack_repro::serving::{PrefetchConfig, ServingConfig, ServingEngine};
use apack_repro::store::{
    append_models, compact_sharded_store, compact_store, pack_model_zoo, pack_model_zoo_sharded,
    pack_model_zoo_sharded_with, pack_model_zoo_with, store_versions, verify_report_json,
    verify_store, Backend, BodyConfig, BodyVersion, FaultConfig, FaultPlan, PackOptions,
    ReadStats, StoreHandle, DEFAULT_CACHE_VALUES,
};
use apack_repro::util::Rng64;

const USAGE: &str = "\
apack-repro — APack off-chip lossless compression, full-system reproduction

USAGE:
  apack-repro compress <input> [--output <file>] [--kind weights|activations] [--substreams N]
  apack-repro decompress <input> --output <file>
  apack-repro store pack <output> [--models a,b|all] [--sample-cap N] [--substreams N] [--min-per-stream N] [--shards N]
                         [--body v1|v2] [--lanes N] [--pipeline on|off] [--pack-workers N] [--trace <file.json>]
  apack-repro store get <store> --tensor NAME [--chunk I | --range LO..HI] [--output <file>] [--backend mmap|file]
                        [--kernel scalar|simd] [--lane-threads N]
                        [--trace <file.json>] [--profile-out <file.folded>] [--prom <file.prom>]
  apack-repro store stats <store> [--backend mmap|file] [--prom <file.prom>] [--json <file|->]
  apack-repro store heatmap <store> [--requests N] [--hot-fraction F] [--prefetch on|off] [--top K]
                            [--backend mmap|file] [--json <file|->] [--prom <file.prom>]
  apack-repro store verify <store> [--backend mmap|file] [--json <file|->]
                           (exit codes: 0 clean, 10 footer, 11 manifest, 12 chunk CRC,
                            13 lane CRC, 14 generation pointer)
  apack-repro store append <store> [--models a,b|all] [--tombstone NAME[,NAME…]]
                           [--sample-cap N] [--substreams N] [--min-per-stream N]
                           [--body v1|v2] [--lanes N] [--pipeline on|off] [--pack-workers N]
  apack-repro store compact <store>
  apack-repro store versions <store>
  apack-repro store report [--sample-cap N]
  apack-repro serve-bench [--models a,b|all] [--workers N] [--queue-depth N] [--clients N]
                          [--requests N] [--coalescing on|off] [--prefetch on|off]
                          [--kernel scalar|simd] [--lane-threads N]
                          [--deadline-ms N] [--hot-fraction F] [--shards N] [--sample-cap N]
                          [--trace <file.json>] [--prom <file.prom>]
                          [--snapshot-jsonl <file.jsonl>] [--snapshot-ms N]
                          [--profile-out <file.folded>] [--exemplars <file.json>]
                          [--slo-ms N] [--slo-objective F] [--slo-availability F]
                          [--inject on] [--inject-rate F] [--inject-seed N] [--inject-budget N]
                          [--compact-mid-run on]
  apack-repro table [--model NAME] [--layer N] [--kind weights|activations]
  apack-repro fig --id <2|5a|5b|6|7|8>
  apack-repro area-power
  apack-repro summary
  apack-repro models
  apack-repro e2e [--artifacts DIR] [--batches N]
";

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv.get(i + 1).cloned().unwrap_or_default();
                flags.insert(key.to_string(), val);
                i += 2;
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }
}

fn parse_kind(s: &str) -> TensorKind {
    if s.eq_ignore_ascii_case("activations") {
        TensorKind::Activations
    } else {
        TensorKind::Weights
    }
}

/// `--models a,b|all` → zoo configs (`default` when the flag is absent).
fn parse_models(args: &Args, default: &str) -> Result<Vec<ModelConfig>, Box<dyn Error>> {
    Ok(match args.flag("models").unwrap_or(default) {
        "all" => all_models(),
        list => list
            .split(',')
            .map(|n| {
                model_by_name(n.trim()).ok_or_else(|| format!("unknown model {}", n.trim()))
            })
            .collect::<Result<Vec<_>, _>>()?,
    })
}

/// An `--flag on`-style switch: on when the flag was given with an empty
/// value (trailing position) or anything other than `off`.
fn switch_flag(args: &Args, key: &str) -> bool {
    args.flag(key).is_some_and(|v| !v.eq_ignore_ascii_case("off"))
}

fn run() -> Result<ExitCode, Box<dyn Error>> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(ExitCode::SUCCESS);
    };
    let args = Args::parse(&argv[1..]);

    match cmd.as_str() {
        "compress" => {
            let input =
                PathBuf::from(args.positional.first().ok_or("missing <input>")?);
            let data = std::fs::read(&input)?;
            let values: Vec<u32> = data.iter().map(|&b| b as u32).collect();
            let substreams: u32 = args.flag_or("substreams", "64").parse()?;
            let mut coord = Coordinator::new(PartitionPolicy {
                substreams,
                ..PartitionPolicy::default()
            });
            let kind = parse_kind(&args.flag_or("kind", "weights"));
            let sc = coord.compress(8, &values, kind, None)?;
            println!(
                "{}: {} values -> {} bits ({:.3} bits/value, ratio {:.2}x, {} shards)",
                input.display(),
                sc.n_values,
                sc.footprint_bits(),
                sc.footprint_bits() as f64 / sc.n_values.max(1) as f64,
                sc.compression_ratio(),
                sc.shards.len()
            );
            if let Some(out) = args.flag("output") {
                std::fs::write(out, sc.to_bytes())?;
                println!("wrote container to {out}");
            }
        }
        "decompress" => {
            let input =
                PathBuf::from(args.positional.first().ok_or("missing <input>")?);
            let output = args.flag("output").ok_or("--output required")?;
            let sc = ShardedContainer::from_bytes(&std::fs::read(&input)?)?;
            let mut coord = Coordinator::new(PartitionPolicy::default());
            let values = coord.decompress(&sc)?;
            let bytes: Vec<u8> = values.iter().map(|&v| v as u8).collect();
            std::fs::write(output, bytes)?;
            println!("decoded {} values to {output}", values.len());
        }
        "table" => {
            let model = args.flag_or("model", "bilstm");
            let layer: usize = args.flag_or("layer", "1").parse()?;
            let kind = parse_kind(&args.flag_or("kind", "weights"));
            match eval::table1::table_for(&model, layer, kind) {
                Some(t) => println!("{}", t.render()),
                None => println!("no such model/layer or tensor not studied"),
            }
        }
        "store" => return run_store(&args),
        "serve-bench" => run_serve_bench(&args)?,
        "fig" => {
            let id = args.flag("id").ok_or("--id required")?;
            match id {
                "2" => println!("{}", eval::fig2::render()),
                "5" | "5a" | "5b" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig5::render(&study));
                }
                "6" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig6::render(&study));
                }
                "7" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig7::render(&study));
                }
                "8" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig8::render(&study));
                }
                other => {
                    return Err(format!("unknown figure id {other} (try 2, 5a, 5b, 6, 7, 8)").into())
                }
            }
        }
        "area-power" => println!("{}", eval::area_power::render()),
        "summary" => {
            let study = CompressionStudy::full();
            println!("{}", eval::fig5::render(&study));
        }
        "models" => {
            for m in all_models() {
                println!(
                    "{:<20} {:?}  {}b  {} layers  {:.2} GMACs  {:.1} M params{}",
                    m.name,
                    m.family,
                    m.bits,
                    m.layers.len(),
                    m.total_macs() as f64 / 1e9,
                    m.total_weights() as f64 / 1e6,
                    if m.in_perf_study { "  [perf-study]" } else { "" }
                );
            }
        }
        "e2e" => {
            let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
            let batches: usize = args.flag_or("batches", "4").parse()?;
            eval::e2e::run(&artifacts, batches)?;
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => return Err(format!("unknown command {other}\n{USAGE}").into()),
    }
    Ok(ExitCode::SUCCESS)
}

/// Render the session read counters (`store get`/`stats`/`serve-bench`
/// footer line). The serving counters (prefetched/coalesced/shed) are
/// zero for plain store commands and light up when the stats come
/// through a `ServingEngine`.
fn read_stats_line(stats: &ReadStats) -> String {
    format!(
        "session reads: {} compressed bytes via {} backend, {} chunks decoded \
         ({} prefetched), cache hit rate {:.1}%, {} coalesced, {} shed\n\
         decode path: {:.1} MB/s per thread over {} values, scratch-pool reuse {:.1}% \
         ({} of {} buffers)\n\
         durability: generation {}, {} transient retries, {} quarantined chunks",
        stats.bytes_read,
        stats.backend.name(),
        stats.chunks_decoded,
        stats.prefetched_chunks,
        100.0 * stats.hit_rate(),
        stats.coalesced_reads,
        stats.shed_requests,
        stats.decode_mb_per_s(),
        stats.values_decoded,
        100.0 * stats.scratch_reuse_rate(),
        stats.scratch_reused,
        stats.scratch_acquired,
        stats.generation,
        stats.transient_retries,
        stats.quarantined_chunks
    )
}

/// Tag for the `store pack` footer: which ingest path produced the stats.
fn pipeline_tag(pipelined: bool) -> &'static str {
    if pipelined {
        "pipelined ingest"
    } else {
        "serial ingest"
    }
}

/// Chunk-body configuration from `--body v1|v2` / `--lanes N` (defaults:
/// v2, [`apack_repro::apack::DEFAULT_LANES`] lanes).
fn parse_body_config(args: &Args) -> Result<BodyConfig, Box<dyn Error>> {
    let body = args.flag_or("body", "v2").to_ascii_lowercase();
    match body.as_str() {
        "v1" | "1" => {
            if args.flag("lanes").is_some() {
                return Err("--lanes only applies to --body v2".into());
            }
            Ok(BodyConfig::v1())
        }
        "v2" | "2" => {
            let lanes: u8 = args
                .flag_or("lanes", &apack_repro::apack::DEFAULT_LANES.to_string())
                .parse()?;
            Ok(BodyConfig::v2(lanes))
        }
        other => Err(format!("unknown --body {other:?} (try v1 or v2)").into()),
    }
}

/// Human tag for a pack's chunk-body configuration.
fn body_tag(body: BodyConfig) -> String {
    match body.version {
        BodyVersion::V1 => "body v1".to_string(),
        BodyVersion::V2 => format!("body v2, {} lanes", body.effective_lanes()),
    }
}

/// `--kernel scalar|simd` → the decode kernel to pin on a store handle
/// (default: [`DecodeKernel::auto`], i.e. the `APACK_DECODE_KERNEL` env
/// override or SIMD with runtime ISA detection).
fn parse_kernel_flag(args: &Args) -> Result<DecodeKernel, Box<dyn Error>> {
    match args.flag("kernel") {
        None => Ok(DecodeKernel::auto()),
        Some(name) => DecodeKernel::from_name(name)
            .ok_or_else(|| format!("unknown --kernel {name:?} (try scalar or simd)").into()),
    }
}

/// Apply `--kernel` / `--lane-threads` to an opened store and return the
/// footer label of the decode loop that will actually run.
fn apply_decode_flags(args: &Args, store: &StoreHandle) -> Result<&'static str, Box<dyn Error>> {
    store.set_decode_kernel(parse_kernel_flag(args)?);
    let lane_threads: usize = args.flag_or("lane-threads", "0").parse()?;
    store.set_lane_threads(lane_threads);
    Ok(store.decode_kernel().active_label())
}

/// Turn the span tracer on when `--trace <file>` was given, returning the
/// output path (tracing stays off — one relaxed atomic load per span
/// site — otherwise).
fn trace_flag(args: &Args) -> Option<PathBuf> {
    let path = args.flag("trace").map(PathBuf::from);
    if path.is_some() {
        obs::enable();
    }
    path
}

/// Stop tracing, write the collected spans as Chrome trace-event JSON,
/// re-read and parse the file (self-validation — a trace that
/// `chrome://tracing` would reject fails the command), and print a
/// one-line summary. Returns the events for further digestion.
fn finish_trace(path: &Path) -> Result<Vec<obs::SpanEvent>, Box<dyn Error>> {
    obs::disable();
    let events = obs::drain();
    obs::write_chrome_trace(path, &events)?;
    let text = std::fs::read_to_string(path)?;
    apack_repro::util::json::Json::parse(&text)
        .map_err(|e| format!("trace self-validation failed: {e}"))?;
    println!(
        "trace: {} spans -> {} (chrome trace-event JSON, parse-checked)",
        events.len(),
        path.display()
    );
    Ok(events)
}

/// Write a Prometheus exposition-format dump of `snap` when `--prom
/// <file>` was given.
fn prom_flag(args: &Args, snap: &obs::RegistrySnapshot) -> Result<(), Box<dyn Error>> {
    if let Some(out) = args.flag("prom") {
        std::fs::write(out, obs::prometheus_text(snap))?;
        println!("metrics: Prometheus text -> {out}");
    }
    Ok(())
}

/// Fold a drained span forest into the per-stage attribution table
/// (ISSUE 8; printed whenever spans were captured) and write the
/// collapsed-stack profile when `--profile-out <file>` was given
/// (flamegraph.pl / speedscope input format).
fn attribution_flag(args: &Args, events: &[obs::SpanEvent]) -> Result<(), Box<dyn Error>> {
    let profile = obs::Profile::from_events(events);
    if profile.is_empty() {
        return Ok(());
    }
    println!("{}", profile.render());
    if let Some(out) = args.flag("profile-out") {
        profile.write_collapsed(Path::new(out))?;
        println!(
            "profile: {} stage paths as collapsed stacks -> {out}",
            profile.iter().count()
        );
    }
    Ok(())
}

/// Write `doc` to `--json <file|->`: a path writes the file, `-` prints
/// the document to stdout.
fn json_out_flag(args: &Args, what: &str, doc: String) -> Result<(), Box<dyn Error>> {
    if let Some(out) = args.flag("json") {
        if out == "-" {
            println!("{doc}");
        } else {
            std::fs::write(out, doc + "\n")?;
            println!("{what}: JSON -> {out}");
        }
    }
    Ok(())
}

/// `store pack | get | stats | heatmap | verify | append | compact |
/// versions | report` — the APackStore CLI. Returns the process exit
/// code: `verify` maps the worst corruption class found to a distinct
/// code (see [`apack_repro::store::CorruptionClass::exit_code`]);
/// everything else exits 0 on success.
fn run_store(args: &Args) -> Result<ExitCode, Box<dyn Error>> {
    let action = args.positional.first().map(String::as_str).unwrap_or("");
    let backend = Backend::parse(&args.flag_or("backend", "mmap"))?;
    match action {
        "pack" => {
            let trace = trace_flag(args);
            let out = args.positional.get(1).ok_or("missing <output> store path")?;
            let models = parse_models(args, "all")?;
            let sample_cap: usize = args.flag_or("sample-cap", "16384").parse()?;
            let substreams: u32 = args.flag_or("substreams", "64").parse()?;
            let min_per_stream: usize = args.flag_or("min-per-stream", "1024").parse()?;
            let shards: usize = args.flag_or("shards", "1").parse()?;
            let policy = PartitionPolicy { substreams, min_per_stream };
            let pipelined = !args.flag_or("pipeline", "on").eq_ignore_ascii_case("off");
            let body = parse_body_config(args)?;
            let opts = PackOptions {
                pipelined,
                workers: args.flag_or("pack-workers", "0").parse()?,
                body,
                ..PackOptions::default()
            };
            if shards > 1 {
                let summary = pack_model_zoo_sharded_with(
                    Path::new(out),
                    &models,
                    sample_cap,
                    policy,
                    shards,
                    &opts,
                )?;
                println!(
                    "packed {} models into {out} ({} shard files): {} tensors, {} chunks, \
                     {:.1} KiB ({:.2}x vs raw sampled values)",
                    models.len(),
                    summary.shards,
                    summary.tensors,
                    summary.chunks,
                    summary.file_bytes as f64 / 1024.0,
                    summary.compression_ratio()
                );
                for (i, s) in summary.per_shard.iter().enumerate() {
                    println!(
                        "  shard-{i:03}: {} tensors, {} chunks, {:.1} KiB",
                        s.tensors,
                        s.chunks,
                        s.file_bytes as f64 / 1024.0
                    );
                }
                println!(
                    "{} ({}, {}, decode kernel {})",
                    summary.pack.render(),
                    pipeline_tag(pipelined),
                    body_tag(body),
                    DecodeKernel::auto().active_label()
                );
            } else {
                let summary =
                    pack_model_zoo_with(Path::new(out), &models, sample_cap, policy, &opts)?;
                println!(
                    "packed {} models into {out}: {} tensors, {} chunks, {:.1} KiB \
                     ({:.2}x vs raw sampled values)",
                    models.len(),
                    summary.tensors,
                    summary.chunks,
                    summary.file_bytes as f64 / 1024.0,
                    summary.compression_ratio()
                );
                println!(
                    "{} ({}, {}, decode kernel {})",
                    summary.pack.render(),
                    pipeline_tag(pipelined),
                    body_tag(body),
                    DecodeKernel::auto().active_label()
                );
            }
            if let Some(p) = trace {
                finish_trace(&p)?;
            }
        }
        "get" => {
            let trace = trace_flag(args);
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let store = StoreHandle::open_with(input, backend, DEFAULT_CACHE_VALUES)?;
            let kernel_label = apply_decode_flags(args, &store)?;
            let name = args.flag("tensor").ok_or("--tensor required")?;
            let values = if let Some(ci) = args.flag("chunk") {
                store.get_chunk(name, ci.parse()?)?.to_vec()
            } else if let Some(range) = args.flag("range") {
                let (lo, hi) = range
                    .split_once("..")
                    .ok_or("--range must look like LO..HI")?;
                store.get_range(name, lo.trim().parse()?..hi.trim().parse()?)?
            } else {
                store.get_tensor(name)?
            };
            let (bv, lanes) = {
                let meta = store.meta(name)?;
                (meta.body_version, meta.lanes)
            };
            println!(
                "{name}: {} values decoded (chunk body v{bv}, {lanes} lane(s), \
                 {kernel_label} kernel)",
                values.len()
            );
            println!("{}", read_stats_line(&store.stats()));
            if let Some(out) = args.flag("output") {
                let mut bytes = Vec::with_capacity(values.len() * 4);
                for v in &values {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                std::fs::write(out, bytes)?;
                println!("wrote little-endian u32 values to {out}");
            } else {
                let head: Vec<String> =
                    values.iter().take(16).map(|v| format!("{v:#x}")).collect();
                let more = if values.len() > 16 { ", …" } else { "" };
                println!("head: [{}{more}]", head.join(", "));
            }
            prom_flag(args, &store.registry_snapshot())?;
            if let Some(p) = trace {
                let events = finish_trace(&p)?;
                attribution_flag(args, &events)?;
            }
        }
        "stats" => {
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let store = StoreHandle::open_with(input, backend, DEFAULT_CACHE_VALUES)?;
            let rows: Vec<Vec<String>> = store
                .tensor_metas()
                .iter()
                .map(|t| {
                    vec![
                        t.name.clone(),
                        format!("{}b", t.bits),
                        format!("{:?}", t.kind),
                        t.n_values.to_string(),
                        t.chunks.len().to_string(),
                        format!("v{}", t.body_version),
                        t.lanes.to_string(),
                        t.compressed_bytes().to_string(),
                        format!(
                            "{:.2}x",
                            t.raw_bits() as f64 / (t.compressed_bytes().max(1) * 8) as f64
                        ),
                    ]
                })
                .collect();
            println!(
                "{}",
                eval::render_table(
                    &format!(
                        "{} — {} tensors, {} shard file(s)",
                        input.display(),
                        store.tensor_count(),
                        store.shard_count()
                    ),
                    &["tensor", "bits", "kind", "values", "chunks", "body", "lanes", "bytes", "ratio"],
                    &rows
                )
            );
            println!("{}", read_stats_line(&store.stats()));
            prom_flag(args, &store.registry_snapshot())?;
            if args.flag("json").is_some() {
                use apack_repro::util::json::Json;
                let tensors: Vec<Json> = store
                    .tensor_metas()
                    .iter()
                    .map(|t| {
                        let mut o = std::collections::BTreeMap::new();
                        o.insert("name".to_string(), Json::Str(t.name.clone()));
                        o.insert("bits".to_string(), Json::Num(t.bits as f64));
                        o.insert("kind".to_string(), Json::Str(format!("{:?}", t.kind)));
                        o.insert("values".to_string(), Json::Num(t.n_values as f64));
                        o.insert("chunks".to_string(), Json::Num(t.chunks.len() as f64));
                        o.insert("body_version".to_string(), Json::Num(t.body_version as f64));
                        o.insert("lanes".to_string(), Json::Num(t.lanes as f64));
                        o.insert(
                            "compressed_bytes".to_string(),
                            Json::Num(t.compressed_bytes() as f64),
                        );
                        o.insert(
                            "ratio".to_string(),
                            Json::Num(
                                t.raw_bits() as f64 / (t.compressed_bytes().max(1) * 8) as f64,
                            ),
                        );
                        Json::Obj(o)
                    })
                    .collect();
                let mut root = std::collections::BTreeMap::new();
                root.insert("store".to_string(), Json::Str(input.display().to_string()));
                root.insert("shards".to_string(), Json::Num(store.shard_count() as f64));
                root.insert("generation".to_string(), Json::Num(store.generation() as f64));
                root.insert("tensor_count".to_string(), Json::Num(store.tensor_count() as f64));
                root.insert("tensors".to_string(), Json::Arr(tensors));
                json_out_flag(args, "stats", Json::Obj(root).to_string())?;
            }
        }
        "heatmap" => {
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let store = StoreHandle::open_with(input, backend, DEFAULT_CACHE_VALUES)?;
            let requests: usize = args.flag_or("requests", "2000").parse()?;
            let hot_fraction: f64 = args.flag_or("hot-fraction", "0.8").parse()?;
            let prefetch_on = !args.flag_or("prefetch", "on").eq_ignore_ascii_case("off");
            let top: usize = args.flag_or("top", "12").parse()?;
            let tensors: Vec<(String, usize)> = store
                .tensor_metas()
                .iter()
                .filter(|t| !t.chunks.is_empty())
                .map(|t| (t.name.clone(), t.chunks.len()))
                .collect();
            if tensors.is_empty() {
                return Err("store holds no non-empty tensors".into());
            }
            // Self-generated traffic, same shape as serve-bench: a small
            // hot pool takes `hot_fraction` of the reads, the rest scatter
            // uniformly. Prefetch warms the hot pool first so the heatmap
            // shows prefetch efficacy, not just demand traffic.
            let hot_pool: Vec<(usize, usize)> = tensors
                .iter()
                .enumerate()
                .flat_map(|(ti, (_, chunks))| [(ti, 0usize), (ti, chunks / 2)])
                .take(8)
                .collect();
            if prefetch_on {
                for &(ti, ci) in &hot_pool {
                    store.prefetch_chunk(&tensors[ti].0, ci)?;
                }
            }
            let mut rng = Rng64::new(0x41EA7);
            for _ in 0..requests {
                let (ti, ci) = if rng.f64() < hot_fraction {
                    hot_pool[rng.below(hot_pool.len() as u64) as usize]
                } else {
                    let ti = rng.below(tensors.len() as u64) as usize;
                    (ti, rng.below(tensors[ti].1 as u64) as usize)
                };
                store.get_chunk(&tensors[ti].0, ci)?;
            }
            let entries = store.heatmap();
            use apack_repro::store::heat;
            println!(
                "{} — {} requests ({:.0}% hot-set, prefetch {})",
                input.display(),
                requests,
                100.0 * hot_fraction,
                if prefetch_on { "on" } else { "off" }
            );
            println!("{}", heat::render_top_chunks(&entries, top));
            println!("{}", heat::render_tensor_summary(&heat::summarize(&entries)));
            println!("{}", read_stats_line(&store.stats()));
            json_out_flag(
                args,
                "heatmap",
                heat::heatmap_json(&input.display().to_string(), &entries).to_string(),
            )?;
            if let Some(out) = args.flag("prom") {
                std::fs::write(out, heat::heatmap_prometheus_text(&entries))?;
                println!("heatmap: per-chunk Prometheus text -> {out}");
            }
        }
        "verify" => {
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let report = verify_store(input, backend);
            json_out_flag(
                args,
                "verify",
                verify_report_json(&input.display().to_string(), &report).to_string(),
            )?;
            if report.is_clean() {
                println!(
                    "{}: OK — {} shard file(s), {} tensors, {} chunks, {} compressed bytes \
                     all pass CRC + decode (generation {})",
                    input.display(),
                    report.shards,
                    report.tensors,
                    report.chunks,
                    report.bytes,
                    report.generation
                );
                // Body-version census: v2 tensors additionally had every
                // lane CRC swept during the verify above.
                let store = StoreHandle::open_with(input, backend, 0)?;
                let mut groups: std::collections::BTreeMap<(u8, u8), usize> =
                    std::collections::BTreeMap::new();
                for t in store.tensor_metas() {
                    *groups.entry((t.body_version, t.lanes)).or_default() += 1;
                }
                let census: Vec<String> = groups
                    .iter()
                    .map(|(&(bv, lanes), &n)| match bv {
                        1 => format!("{n} × body v1"),
                        _ => format!("{n} × body v{bv} ({lanes} lanes, per-lane CRCs swept)"),
                    })
                    .collect();
                println!("chunk bodies: {}", census.join(", "));
                return Ok(ExitCode::SUCCESS);
            }
            println!(
                "{}: {} issue(s) — {} shard file(s), {} tensors, {} chunks swept, \
                 {} clean bytes (generation {})",
                input.display(),
                report.issues.len(),
                report.shards,
                report.tensors,
                report.chunks,
                report.bytes,
                report.generation
            );
            let mut by_class: std::collections::BTreeMap<&str, usize> =
                std::collections::BTreeMap::new();
            for issue in &report.issues {
                println!("  {}", issue.render());
                *by_class.entry(issue.class.label()).or_default() += 1;
            }
            let census: Vec<String> =
                by_class.iter().map(|(label, n)| format!("{n} × {label}")).collect();
            let worst = report.worst_class().expect("unclean report has a worst class");
            println!(
                "by class: {} — worst {} (exit code {})",
                census.join(", "),
                worst.label(),
                worst.exit_code()
            );
            return Ok(ExitCode::from(worst.exit_code()));
        }
        "append" => {
            let out = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let models = match args.flag("models") {
                Some(_) => parse_models(args, "all")?,
                None => Vec::new(),
            };
            let tombstones: Vec<String> = args
                .flag("tombstone")
                .map(|s| {
                    s.split(',')
                        .map(|t| t.trim().to_string())
                        .filter(|t| !t.is_empty())
                        .collect()
                })
                .unwrap_or_default();
            if models.is_empty() && tombstones.is_empty() {
                return Err("store append needs --models and/or --tombstone".into());
            }
            let sample_cap: usize = args.flag_or("sample-cap", "16384").parse()?;
            let substreams: u32 = args.flag_or("substreams", "64").parse()?;
            let min_per_stream: usize = args.flag_or("min-per-stream", "1024").parse()?;
            let policy = PartitionPolicy { substreams, min_per_stream };
            let pipelined = !args.flag_or("pipeline", "on").eq_ignore_ascii_case("off");
            let opts = PackOptions {
                pipelined,
                workers: args.flag_or("pack-workers", "0").parse()?,
                body: parse_body_config(args)?,
                ..PackOptions::default()
            };
            let summary = append_models(out, &models, sample_cap, &policy, &opts, &tombstones)?;
            println!(
                "committed generation {} to {}: {} live tensors ({} added, {} replaced, \
                 {} tombstoned), {:.1} KiB appended, {:.1} KiB committed",
                summary.generation,
                out.display(),
                summary.tensors,
                summary.tensors_added,
                summary.tensors_replaced,
                summary.tombstoned,
                summary.bytes_written as f64 / 1024.0,
                summary.file_bytes as f64 / 1024.0
            );
        }
        "compact" => {
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let summary = if input.is_dir() {
                compact_sharded_store(input, None)?
            } else {
                compact_store(input, None)?
            };
            println!(
                "compacted {} to generation {}: {} tensors, {} chunks, {:.1} KiB -> \
                 {:.1} KiB ({:.1} KiB reclaimed)",
                input.display(),
                summary.generation,
                summary.tensors,
                summary.chunks,
                summary.bytes_before as f64 / 1024.0,
                summary.bytes_after as f64 / 1024.0,
                summary.reclaimed() as f64 / 1024.0
            );
        }
        "versions" => {
            let input = Path::new(args.positional.get(1).ok_or("missing <store> path")?);
            let versions = store_versions(input)?;
            let rows: Vec<Vec<String>> = versions
                .iter()
                .map(|v| {
                    vec![
                        v.shard.map_or("-".to_string(), |s| s.to_string()),
                        v.generation.to_string(),
                        v.tensors.to_string(),
                        v.trailer_offset.to_string(),
                        v.committed_len.to_string(),
                    ]
                })
                .collect();
            println!(
                "{}",
                eval::render_table(
                    &format!(
                        "{} — {} committed generation(s)",
                        input.display(),
                        versions.len()
                    ),
                    &["shard", "gen", "tensors", "trailer@", "bytes"],
                    &rows
                )
            );
        }
        "report" => {
            let sample_cap: usize = args.flag_or("sample-cap", "8192").parse()?;
            println!("{}", eval::store_report::render(sample_cap)?);
        }
        other => {
            return Err(format!(
                "unknown store action {other:?} (try pack, get, stats, heatmap, verify, \
                 append, compact, versions, report)"
            )
            .into())
        }
    }
    Ok(ExitCode::SUCCESS)
}

/// `serve-bench` — closed-loop clients through a [`ServingEngine`] over a
/// freshly packed zoo store: the serving layer's throughput/latency/
/// shedding profile in one command.
fn run_serve_bench(args: &Args) -> Result<(), Box<dyn Error>> {
    let models = match args.flag("models").unwrap_or("resnet18,ncf,bilstm,alexnet_eyeriss") {
        "all" => all_models(),
        list => list
            .split(',')
            .map(|n| {
                model_by_name(n.trim()).ok_or_else(|| format!("unknown model {}", n.trim()))
            })
            .collect::<Result<Vec<_>, _>>()?,
    };
    let workers: usize = args.flag_or("workers", "0").parse()?; // 0 = auto
    let queue_depth: usize = args.flag_or("queue-depth", "256").parse()?;
    let clients: usize = args.flag_or("clients", "8").parse()?;
    let requests: usize = args.flag_or("requests", "400").parse()?;
    let coalescing = !args.flag("coalescing").is_some_and(|v| v == "off");
    let prefetch_on = !args.flag("prefetch").is_some_and(|v| v == "off");
    let deadline_ms: u64 = args.flag_or("deadline-ms", "0").parse()?; // 0 = none
    let hot_fraction: f64 = args.flag_or("hot-fraction", "0.8").parse()?;
    let shards: usize = args.flag_or("shards", "1").parse()?;
    let sample_cap: usize = args.flag_or("sample-cap", "8192").parse()?;
    let slo_ms: u64 = args.flag_or("slo-ms", "0").parse()?; // 0 = no SLO tracking
    let slo_objective: f64 = args.flag_or("slo-objective", "0.99").parse()?;
    let slo_availability: f64 = args.flag_or("slo-availability", "0.99").parse()?;
    // Fault injection (`--inject on` picks a default rate; an explicit
    // `--inject-rate` implies injection on its own).
    let inject_rate: f64 = match args.flag("inject-rate") {
        Some(v) => v.parse()?,
        None if switch_flag(args, "inject") => 0.02,
        None => 0.0,
    };
    let inject_seed: u64 = args.flag_or("inject-seed", "64023").parse()?;
    let inject_budget: u64 = match args.flag("inject-budget") {
        Some(v) => v.parse()?,
        None => u64::MAX,
    };
    let compact_mid_run = switch_flag(args, "compact-mid-run");

    let path = std::env::temp_dir()
        .join(format!("apack_serve_bench_{}.apackstore", std::process::id()));
    let policy = PartitionPolicy { substreams: 16, min_per_stream: 512 };
    if shards > 1 {
        pack_model_zoo_sharded(&path, &models, sample_cap, policy, shards)?;
    } else {
        pack_model_zoo(&path, &models, sample_cap, policy)?;
    }
    let plan = (inject_rate > 0.0).then(|| {
        FaultPlan::new(FaultConfig {
            seed: inject_seed,
            read_error_rate: inject_rate,
            short_read_rate: inject_rate / 2.0,
            latency_spike_rate: inject_rate,
            max_injected_errors: inject_budget,
            ..FaultConfig::default()
        })
    });
    let store = Arc::new(StoreHandle::open_with_plan(
        &path,
        Backend::default(),
        DEFAULT_CACHE_VALUES,
        plan.as_ref(),
    )?);
    let kernel_label = apply_decode_flags(args, &store)?;

    // Owned tensor directory so client threads need no store borrows.
    let tensors: Vec<(String, u64, usize)> = store
        .tensor_metas()
        .iter()
        .filter(|t| !t.chunks.is_empty())
        .map(|t| (t.name.clone(), t.n_values, t.chunks.len()))
        .collect();
    if tensors.is_empty() {
        return Err("packed store holds no non-empty tensors".into());
    }
    // A small hot pool spread across tensors: `hot_fraction` of requests
    // land here, exercising coalescing and the prefetcher.
    let hot_pool: Vec<(String, usize)> = tensors
        .iter()
        .flat_map(|(name, _, chunks)| {
            [(name.clone(), 0usize), (name.clone(), chunks / 2)]
        })
        .take(8)
        .collect();

    let config = ServingConfig {
        workers: if workers == 0 { ServingConfig::default().workers } else { workers },
        queue_depth,
        coalescing,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        prefetch: prefetch_on.then(PrefetchConfig::default),
        slo: (slo_ms > 0).then(|| obs::SloConfig {
            latency_target: Duration::from_millis(slo_ms),
            latency_objective: slo_objective,
            availability_objective: slo_availability,
            ..obs::SloConfig::default()
        }),
    };
    println!(
        "serve-bench: {} tensors over {} shard(s), {} workers, queue depth {}, \
         coalescing {}, prefetch {}, {kernel_label} kernel, {} clients × {} requests \
         ({:.0}% hot-set)",
        tensors.len(),
        store.shard_count(),
        config.workers,
        config.queue_depth,
        if coalescing { "on" } else { "off" },
        if prefetch_on { "on" } else { "off" },
        clients,
        requests,
        100.0 * hot_fraction
    );
    if inject_rate > 0.0 {
        println!(
            "fault injection armed: rate {inject_rate}, seed {inject_seed}, budget {}",
            if inject_budget == u64::MAX {
                "unbounded".to_string()
            } else {
                inject_budget.to_string()
            }
        );
    }
    let trace = trace_flag(args);
    let engine = ServingEngine::start(Arc::clone(&store), config)?;
    let snapshots = match args.flag("snapshot-jsonl") {
        Some(out) => {
            let interval: u64 = args.flag_or("snapshot-ms", "200").parse()?;
            Some((
                out.to_string(),
                obs::SnapshotStream::start(
                    Path::new(out),
                    Duration::from_millis(interval.max(1)),
                    engine.snapshot_source(),
                )?,
            ))
        }
        None => None,
    };

    let t0 = Instant::now();
    let mut ok = 0u64;
    let mut shed = 0u64;
    let mut failed = 0u64;
    let mut served_values = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        if compact_mid_run {
            let store = Arc::clone(&store);
            scope.spawn(move || {
                // Let some traffic build up, then compact while serving:
                // in-flight requests keep their pinned generation, new
                // requests land on the compacted one.
                std::thread::sleep(Duration::from_millis(50));
                match store.compact_live() {
                    Ok(s) => println!(
                        "mid-run compaction: generation {} ({:.1} KiB reclaimed) while serving",
                        s.generation,
                        s.reclaimed() as f64 / 1024.0
                    ),
                    Err(e) => eprintln!("mid-run compaction failed: {e}"),
                }
            });
        }
        for tid in 0..clients {
            let engine = &engine;
            let tensors = &tensors;
            let hot_pool = &hot_pool;
            handles.push(scope.spawn(move || {
                let mut rng = Rng64::new(0xC11E27 ^ ((tid as u64) << 10));
                let (mut ok, mut shed, mut failed, mut served) = (0u64, 0u64, 0u64, 0u64);
                for _ in 0..requests {
                    let result = if rng.f64() < hot_fraction {
                        let (name, ci) = &hot_pool[rng.below(hot_pool.len() as u64) as usize];
                        engine.get_chunk(name, *ci)
                    } else {
                        let (name, n_values, chunks) =
                            &tensors[rng.below(tensors.len() as u64) as usize];
                        if rng.chance(0.5) {
                            let lo = rng.below(*n_values);
                            let span = 1 + rng.below((*n_values - lo).min(4096));
                            engine.get_range(name, lo..(lo + span).min(*n_values))
                        } else {
                            engine.get_chunk(name, rng.below(*chunks as u64) as usize)
                        }
                    };
                    match result {
                        Ok(values) => {
                            ok += 1;
                            served += values.len() as u64;
                        }
                        Err(apack_repro::Error::Overloaded { .. }) => shed += 1,
                        Err(e) => {
                            eprintln!("serve-bench read failed: {e}");
                            failed += 1;
                        }
                    }
                }
                (ok, shed, failed, served)
            }));
        }
        for handle in handles {
            let (o, s, f, v) = handle.join().expect("serve-bench client");
            ok += o;
            shed += s;
            failed += f;
            served_values += v;
        }
    });
    let dt = t0.elapsed();

    let total = (clients * requests) as f64;
    println!(
        "{ok} ok / {shed} shed / {failed} failed in {dt:?} ({:.0} requests/s, \
         {:.1} Mvalues/s)",
        total / dt.as_secs_f64(),
        served_values as f64 / dt.as_secs_f64() / 1e6
    );
    println!("{}", engine.metrics().render());
    println!("{}", read_stats_line(&engine.stats()));
    if let Some(plan) = &plan {
        println!(
            "fault injection: {} transient faults injected over {} reads",
            plan.injected_errors(),
            plan.reads()
        );
    }
    if let Some((out, stream)) = snapshots {
        drop(stream); // flush the final snapshot line before reporting
        println!("metrics: periodic JSONL snapshots -> {out}");
    }
    prom_flag(args, &engine.registry_snapshot())?;
    if let Some(p) = trace {
        let events = finish_trace(&p)?;
        match obs::request_coverage(&events) {
            Some(cov) => println!(
                "trace coverage: stage spans account for {:.1}% of the median \
                 request's wall-clock (acceptance floor 95%)",
                100.0 * cov
            ),
            None => println!("trace coverage: no request spans captured"),
        }
        attribution_flag(args, &events)?;
        // Tail sampler: join span trees with the engine's outcome ring
        // and keep the slowest-decile / errored / shed requests.
        let ring = obs::collect_exemplars(&events, &engine.request_outcomes(), 32);
        if !ring.is_empty() {
            println!("{}", ring.render());
        }
        if let Some(out) = args.flag("exemplars") {
            ring.write_chrome_trace(Path::new(out))?;
            let text = std::fs::read_to_string(out)?;
            apack_repro::util::json::Json::parse(&text)
                .map_err(|e| format!("exemplar trace self-validation failed: {e}"))?;
            println!(
                "exemplars: {} tail span trees -> {out} (chrome trace-event JSON, \
                 parse-checked)",
                ring.exemplars().len()
            );
        }
    }
    let slo_breach = engine.slo_status().filter(|s| s.breaching());
    drop(engine);
    drop(store);
    if path.is_dir() {
        std::fs::remove_dir_all(&path).ok();
    } else {
        std::fs::remove_file(&path).ok();
    }
    if failed > 0 {
        return Err(format!("{failed} requests failed with non-overload errors").into());
    }
    if let Some(status) = slo_breach {
        return Err(format!(
            "SLO breach: latency burn {:.2}/{:.2} (fast/slow), availability burn \
             {:.2}/{:.2}, threshold {:.2} — see the serving report above",
            status.latency.fast_burn,
            status.latency.slow_burn,
            status.availability.fast_burn,
            status.availability.slow_burn,
            status.burn_threshold
        )
        .into());
    }
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
