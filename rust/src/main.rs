//! `apack-repro` CLI: compress/decompress tensors, print the paper's
//! tables and figures, and run the end-to-end PJRT inference demo.
//!
//! (Argument parsing is hand-rolled — this build environment has no clap.)

use std::path::PathBuf;
use std::process::ExitCode;

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::{Coordinator, PartitionPolicy, ShardedContainer};
use apack_repro::eval::{self, CompressionStudy};
use apack_repro::models::zoo::all_models;

const USAGE: &str = "\
apack-repro — APack off-chip lossless compression, full-system reproduction

USAGE:
  apack-repro compress <input> [--output <file>] [--kind weights|activations] [--substreams N]
  apack-repro decompress <input> --output <file>
  apack-repro table [--model NAME] [--layer N] [--kind weights|activations]
  apack-repro fig --id <2|5a|5b|6|7|8>
  apack-repro area-power
  apack-repro summary
  apack-repro models
  apack-repro e2e [--artifacts DIR] [--batches N]
";

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv.get(i + 1).cloned().unwrap_or_default();
                flags.insert(key.to_string(), val);
                i += 2;
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }
}

fn parse_kind(s: &str) -> TensorKind {
    if s.eq_ignore_ascii_case("activations") {
        TensorKind::Activations
    } else {
        TensorKind::Weights
    }
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);

    match cmd.as_str() {
        "compress" => {
            let input = PathBuf::from(
                args.positional.first().ok_or_else(|| anyhow::anyhow!("missing <input>"))?,
            );
            let data = std::fs::read(&input)?;
            let values: Vec<u32> = data.iter().map(|&b| b as u32).collect();
            let substreams: u32 = args.flag_or("substreams", "64").parse()?;
            let mut coord = Coordinator::new(PartitionPolicy {
                substreams,
                ..PartitionPolicy::default()
            });
            let kind = parse_kind(&args.flag_or("kind", "weights"));
            let sc = coord.compress(8, &values, kind, None)?;
            println!(
                "{}: {} values -> {} bits ({:.3} bits/value, ratio {:.2}x, {} shards)",
                input.display(),
                sc.n_values,
                sc.footprint_bits(),
                sc.footprint_bits() as f64 / sc.n_values.max(1) as f64,
                sc.compression_ratio(),
                sc.shards.len()
            );
            if let Some(out) = args.flag("output") {
                std::fs::write(out, sc.to_bytes())?;
                println!("wrote container to {out}");
            }
        }
        "decompress" => {
            let input = PathBuf::from(
                args.positional.first().ok_or_else(|| anyhow::anyhow!("missing <input>"))?,
            );
            let output = args.flag("output").ok_or_else(|| anyhow::anyhow!("--output required"))?;
            let sc = ShardedContainer::from_bytes(&std::fs::read(&input)?)?;
            let mut coord = Coordinator::new(PartitionPolicy::default());
            let values = coord.decompress(&sc)?;
            let bytes: Vec<u8> = values.iter().map(|&v| v as u8).collect();
            std::fs::write(output, bytes)?;
            println!("decoded {} values to {output}", values.len());
        }
        "table" => {
            let model = args.flag_or("model", "bilstm");
            let layer: usize = args.flag_or("layer", "1").parse()?;
            let kind = parse_kind(&args.flag_or("kind", "weights"));
            match eval::table1::table_for(&model, layer, kind) {
                Some(t) => println!("{}", t.render()),
                None => println!("no such model/layer or tensor not studied"),
            }
        }
        "fig" => {
            let id = args.flag("id").ok_or_else(|| anyhow::anyhow!("--id required"))?;
            match id {
                "2" => println!("{}", eval::fig2::render()),
                "5" | "5a" | "5b" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig5::render(&study));
                }
                "6" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig6::render(&study));
                }
                "7" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig7::render(&study));
                }
                "8" => {
                    let study = CompressionStudy::full();
                    println!("{}", eval::fig8::render(&study));
                }
                other => anyhow::bail!("unknown figure id {other} (try 2, 5a, 5b, 6, 7, 8)"),
            }
        }
        "area-power" => println!("{}", eval::area_power::render()),
        "summary" => {
            let study = CompressionStudy::full();
            println!("{}", eval::fig5::render(&study));
        }
        "models" => {
            for m in all_models() {
                println!(
                    "{:<20} {:?}  {}b  {} layers  {:.2} GMACs  {:.1} M params{}",
                    m.name,
                    m.family,
                    m.bits,
                    m.layers.len(),
                    m.total_macs() as f64 / 1e9,
                    m.total_weights() as f64 / 1e6,
                    if m.in_perf_study { "  [perf-study]" } else { "" }
                );
            }
        }
        "e2e" => {
            let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
            let batches: usize = args.flag_or("batches", "4").parse()?;
            eval::e2e::run(&artifacts, batches)?;
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => anyhow::bail!("unknown command {other}\n{USAGE}"),
    }
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
