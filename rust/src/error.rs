//! Crate-wide error type.

use std::fmt;

/// Errors produced by the APack codec, coordinator, simulator, store and
/// serving layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// A symbol/probability table failed validation (the contained string
    /// describes the violated invariant).
    InvalidTable(String),
    /// A value to be encoded falls in a range whose probability count is
    /// zero — the table does not cover it. Contains the offending value.
    ValueNotCovered(u32),
    /// A value exceeds the bit width the table was built for.
    ValueOutOfRange { value: u32, bits: u32 },
    /// The compressed symbol stream is corrupt (code register escaped every
    /// scaled probability-count range).
    CorruptStream { position: usize },
    /// The container metadata is inconsistent (framing, counts, versions).
    BadContainer(String),
    /// An APackStore file is malformed or fails an integrity check
    /// (truncated footer, CRC mismatch, index pointing past EOF, …).
    Store(String),
    /// A sharded store's manifest is unreadable or fails validation
    /// (bad magic, bad CRC, truncated records, inconsistent counts).
    ManifestCorrupt(String),
    /// A shard file named by the manifest is absent from the store
    /// directory.
    ShardMissing { shard: String },
    /// The store directory holds a different number of shard files than
    /// the manifest declares.
    ShardCountMismatch { manifest: usize, found: usize },
    /// The serving layer shed this request instead of queueing it without
    /// bound: the admission queue was already `queue_depth` requests deep
    /// at submit time, or — when `deadline_expired` — the request's
    /// deadline passed before a worker picked it up. Overload surfaces as
    /// this typed error, never as unbounded latency.
    Overloaded { queue_depth: usize, deadline_expired: bool },
    /// Underlying I/O failure, stringified (keeps the error type `Eq`).
    Io(String),
    /// A *transient* I/O failure (interrupted read, injected flake,
    /// timeout) that is expected to succeed on retry. Retried with
    /// bounded jittered backoff by the store/serving layers and — unlike
    /// permanent corruption — never shared with coalesced single-flight
    /// followers (DESIGN.md §14).
    Transient(String),
    /// Configuration error (coordinator / simulator parameters).
    Config(String),
    /// Runtime (PJRT / artifact) error, stringified.
    Runtime(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidTable(s) => write!(f, "invalid APack table: {s}"),
            Error::ValueNotCovered(v) => {
                write!(f, "value {v:#x} maps to a zero-probability range")
            }
            Error::ValueOutOfRange { value, bits } => {
                write!(f, "value {value:#x} out of range for {bits}-bit table")
            }
            Error::CorruptStream { position } => {
                write!(f, "corrupt symbol stream at symbol {position}")
            }
            Error::BadContainer(s) => write!(f, "bad container: {s}"),
            Error::Store(s) => write!(f, "bad store: {s}"),
            Error::ManifestCorrupt(s) => write!(f, "corrupt shard manifest: {s}"),
            Error::ShardMissing { shard } => {
                write!(f, "shard file {shard:?} named by the manifest is missing")
            }
            Error::ShardCountMismatch { manifest, found } => write!(
                f,
                "manifest declares {manifest} shard files but the directory holds {found}"
            ),
            Error::Overloaded { queue_depth, deadline_expired } => {
                if *deadline_expired {
                    write!(
                        f,
                        "serving overloaded: deadline expired before a worker picked the \
                         request up (queue depth {queue_depth})"
                    )
                } else {
                    write!(
                        f,
                        "serving overloaded: admission queue full at {queue_depth} requests"
                    )
                }
            }
            Error::Io(s) => write!(f, "i/o error: {s}"),
            Error::Transient(s) => write!(f, "transient i/o error: {s}"),
            Error::Config(s) => write!(f, "configuration error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
        }
    }
}

impl Error {
    /// True for errors worth retrying (the failure is not expected to
    /// repeat deterministically).
    pub fn is_transient(&self) -> bool {
        matches!(self, Error::Transient(_))
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut => Error::Transient(e.to_string()),
            _ => Error::Io(e.to_string()),
        }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
