//! Minimal JSON parser/serializer (serde stand-in) — enough for the AOT
//! artifact manifest and the CLI's container metadata: objects, arrays,
//! strings (with escapes), integers/floats, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let doc = r#"{
            "hlo": "model.hlo.txt",
            "input_shape": [8, 3, 32, 32],
            "bits": 8,
            "weights": [
                {"name": "conv1_w", "shape": [16, 3, 3, 3], "file": "w0.bin"},
                {"name": "fc_w", "shape": [256, 10], "file": "w1.bin"}
            ],
            "outputs": ["logits", "act_conv1"]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("hlo").unwrap().as_str().unwrap(), "model.hlo.txt");
        assert_eq!(j.get("bits").unwrap().as_usize().unwrap(), 8);
        let shape: Vec<usize> = j
            .get("input_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![8, 3, 32, 32]);
        let w = j.get("weights").unwrap().as_arr().unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[1].get("name").unwrap().as_str().unwrap(), "fc_w");
    }

    #[test]
    fn roundtrip_with_escapes_and_nesting() {
        let doc = r#"{"a": "x\"y\\z\nw", "b": [1, 2.5, -3e2, true, false, null], "c": {}}"#;
        let j = Json::parse(doc).unwrap();
        let s = j.to_string();
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j.get("a").unwrap().as_str().unwrap(), "x\"y\\z\nw");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }
}
