//! Deterministic pseudo-random number generation: splitmix64 seeding into
//! xoshiro256**, the standard high-quality non-cryptographic generator.
//! Used for all synthetic tensor generation so every figure is exactly
//! reproducible from a seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free (tiny bias acceptable for tests and
        // synthesis; never used for ranges near 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(1);
        let mut c = Rng64::new(2);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng64::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }
}
