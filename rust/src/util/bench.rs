//! Tiny benchmark harness (criterion stand-in): warmup + timed iterations,
//! reporting median/mean/min wall time and derived throughput. Bench
//! binaries (`benches/*.rs`, `harness = false`) call [`Bench::run`].

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl BenchStats {
    /// Pretty one-line report; `bytes_per_iter` adds throughput.
    pub fn report(&self, bytes_per_iter: Option<u64>) -> String {
        let mut s = format!(
            "{:<44} {:>10.3?} median  {:>10.3?} mean  {:>10.3?} min  ({} iters)",
            self.name, self.median, self.mean, self.min, self.iters
        );
        if let Some(b) = bytes_per_iter {
            let gbs = b as f64 / self.median.as_secs_f64() / 1e9;
            s.push_str(&format!("  {gbs:.3} GB/s"));
        }
        s
    }
}

/// Benchmark runner configuration.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 2, iters: 10 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 5 }
    }

    /// Run `f` and collect stats. The closure's return value is
    /// black-boxed to keep the work alive.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchStats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let min = times[0];
        BenchStats { name: name.to_string(), iters: self.iters, median, mean, min }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bench { warmup: 1, iters: 5 };
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.min <= s.median);
        assert_eq!(s.iters, 5);
        assert!(s.report(Some(80_000)).contains("GB/s"));
    }
}
