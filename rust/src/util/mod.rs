//! In-tree utility substrates (this build environment is offline, so the
//! usual crates — rand, serde, rayon, clap, criterion, proptest — are
//! replaced by the minimal implementations here; see DESIGN.md).

pub mod bench;
pub mod json;
pub mod par;
pub mod rng;

pub use par::{par_map, par_map_owned, par_map_owned_with, par_map_with};
pub use rng::Rng64;
