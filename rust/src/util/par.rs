//! Minimal data-parallel map over std scoped threads (rayon stand-in).
//!
//! One chunking/spawn/collect core ([`par_map_owned_with`]) serves both
//! the borrowing map ([`par_map`], [`par_map_with`]) and the owned-item
//! map ([`par_map_owned`]) whose items may carry `&mut` borrows (e.g.
//! disjoint sub-slices of one output buffer — the coordinator's
//! decode-into-slice path).

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map preserving order: splits `items` across up to the
/// available-parallelism worker count.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_with(items, default_threads(), f)
}

/// Parallel map with an explicit worker count.
pub fn par_map_with<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_owned_with(items.iter().collect(), threads, |item| f(item))
}

/// Parallel map over **owned** items, preserving order (each item is moved
/// into the closure).
pub fn par_map_owned<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    par_map_owned_with(items, default_threads(), f)
}

/// The shared core: order-preserving scoped-thread map over owned items
/// with an explicit worker count.
pub fn par_map_owned_with<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|scope| {
        for (in_chunk, out_chunk) in items.chunks_mut(chunk).zip(out.chunks_mut(chunk)) {
            scope.spawn(move || {
                for (item, slot) in in_chunk.iter_mut().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item.take().expect("item present")));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn works_with_one_thread_and_empty() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map_with(&items, 1, |&x| x + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(par_map(&empty, |&x: &i32| x).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let items = vec![5];
        assert_eq!(par_map_with(&items, 64, |&x| x), vec![5]);
    }

    #[test]
    fn owned_map_supports_mutable_slices() {
        let mut buf = vec![0u32; 100];
        let jobs: Vec<(u32, &mut [u32])> =
            buf.chunks_mut(10).enumerate().map(|(i, c)| (i as u32, c)).collect();
        let lens = par_map_owned(jobs, |(i, slice)| {
            slice.fill(i);
            slice.len()
        });
        assert_eq!(lens, vec![10; 10]);
        for (i, chunk) in buf.chunks(10).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32));
        }
        assert!(par_map_owned(Vec::<u8>::new(), |x| x).is_empty());
    }
}
