//! Minimal data-parallel map over std scoped threads (rayon stand-in).

/// Parallel map preserving order: splits `items` across up to `threads`
/// workers (defaults to available parallelism).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    par_map_with(items, threads, f)
}

/// Parallel map with an explicit worker count.
pub fn par_map_with<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|scope| {
        for (items_chunk, out_chunk) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            scope.spawn(move || {
                for (item, slot) in items_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn works_with_one_thread_and_empty() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map_with(&items, 1, |&x| x + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(par_map(&empty, |&x: &i32| x).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let items = vec![5];
        assert_eq!(par_map_with(&items, 64, |&x| x), vec![5]);
    }
}
