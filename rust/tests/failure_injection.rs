//! Failure-injection tests: corrupt streams, mismatched tables, truncated
//! containers, hostile manifests — the decoder must fail loudly (error or
//! detectable mismatch), never loop or panic.

use apack_repro::apack::bitstream::BitReader;
use apack_repro::apack::decoder::ApackDecoder;
use apack_repro::apack::encoder::ApackEncoder;
use apack_repro::apack::tablegen::{table_for_tensor, TensorKind};
use apack_repro::apack::{Container, SymbolTable};
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::runtime::ArtifactManifest;
use apack_repro::store::format::{
    crc32, gen_pointer_path, trailer_bytes, StoreFormat, StoreIndex, TRAILER_BYTES,
};
use apack_repro::store::{
    compact_store, encode_tensor_with, shard_file_name, shard_for_name, verify_store, Backend,
    BodyConfig, CorruptionClass, FaultConfig, FaultPlan, ShardedStoreAppender,
    ShardedStoreReader, ShardedStoreWriter, StoreAppender, StoreHandle, StoreReader,
    StoreWriter, MANIFEST_FILE,
};
use apack_repro::util::Rng64;
use apack_repro::Error;

fn sample_tensor(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng64::new(seed);
    (0..n).map(|_| if rng.chance(0.5) { 0 } else { rng.below(256) as u32 }).collect()
}

/// Decoding with a *different* table than the encoder used must not
/// reproduce the input (and must not panic / hang).
#[test]
fn wrong_table_never_silently_succeeds() {
    let values = sample_tensor(2000, 1);
    let t1 = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let t2 = SymbolTable::uniform(8);
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t1, &values).unwrap();
    let mut ofs_r = BitReader::new(&ofs, ob);
    match ApackDecoder::decode_all(&t2, BitReader::new(&sym, sb), &mut ofs_r, values.len()) {
        Ok(decoded) => assert_ne!(decoded, values, "wrong table decoded correctly?!"),
        Err(_) => {} // detected — fine
    }
}

/// Every single-bit flip in the symbol stream is either detected or
/// changes the output (no silent correct decode of corrupt data).
#[test]
fn symbol_stream_bit_flips() {
    let values = sample_tensor(512, 2);
    let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
    let mut undetected_identical = 0;
    for flip in (0..sym.len().min(32)).map(|i| i * 7 % sym.len()) {
        let mut bad = sym.clone();
        bad[flip] ^= 1 << (flip % 8);
        let mut ofs_r = BitReader::new(&ofs, ob);
        match ApackDecoder::decode_all(&t, BitReader::new(&bad, sb), &mut ofs_r, values.len()) {
            Ok(decoded) if decoded == values => undetected_identical += 1,
            _ => {}
        }
    }
    assert_eq!(undetected_identical, 0, "bit flips must never decode identically");
}

/// Truncated symbol stream: decode must terminate (zero-padding semantics)
/// with an error or a mismatch, never hang.
#[test]
fn truncated_symbol_stream_terminates() {
    let values = sample_tensor(4096, 3);
    let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
    for keep in [0usize, 1, sb / 4, sb / 2] {
        let mut ofs_r = BitReader::new(&ofs, ob);
        let result = ApackDecoder::decode_all(
            &t,
            BitReader::new(&sym, keep.min(sb)),
            &mut ofs_r,
            values.len(),
        );
        if let Ok(decoded) = result {
            assert_ne!(decoded, values, "keep={keep}");
        }
    }
}

/// Truncated offset stream: the decoder must fail with a typed
/// `CorruptStream` at the first value whose offset bits are missing —
/// never silently fabricate zero offsets (the zero-latch is reserved for
/// the symbol stream, whose flush provably tolerates it).
#[test]
fn truncated_offset_stream_is_corrupt() {
    let values = sample_tensor(4096, 4);
    let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
    if ob == 0 {
        return; // degenerate: all singleton ranges
    }
    let mut ofs_r = BitReader::new(&ofs, ob / 4);
    match ApackDecoder::decode_all(&t, BitReader::new(&sym, sb), &mut ofs_r, values.len()) {
        Ok(_) => panic!("decode with 3/4 of the offset bits missing must fail"),
        Err(Error::CorruptStream { position }) => {
            assert!(position < values.len(), "error position {position} out of range")
        }
        Err(e) => panic!("expected CorruptStream, got {e}"),
    }
}

/// Container parser fuzz: random byte soup never panics.
#[test]
fn container_from_bytes_fuzz() {
    let mut rng = Rng64::new(99);
    for _ in 0..200 {
        let n = rng.range(0, 400);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = Container::from_bytes(&bytes); // must not panic
    }
    // And a structurally-valid header with garbage body.
    let values = sample_tensor(100, 5);
    let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
    let c = Container {
        table: t,
        n_values: values.len() as u64,
        symbols: sym,
        symbol_bits: sb as u64,
        offsets: ofs,
        offset_bits: ob as u64,
    };
    let mut bytes = c.to_bytes();
    for i in 6..bytes.len().min(60) {
        bytes[i] = bytes[i].wrapping_add(0x5A);
    }
    let _ = Container::from_bytes(&bytes); // error or garbage, no panic
}

/// Hostile manifests: parser rejects or tolerates, never panics.
#[test]
fn manifest_fuzz() {
    let cases = [
        "",
        "{}",
        "null",
        "[1,2,3]",
        r#"{"hlo": 5, "input_shape": "x", "weights": {}}"#,
        r#"{"hlo": "m", "input_shape": [1e99], "weights": [{"name":"w","shape":[-1],"file":"f"}]}"#,
        r#"{"hlo": "m", "input_shape": [], "weights": [], "outputs": [null]}"#,
    ];
    for c in cases {
        let _ = ArtifactManifest::from_json(c); // must not panic
    }
    let mut rng = Rng64::new(7);
    for _ in 0..100 {
        let n = rng.range(0, 200);
        let soup: String =
            (0..n).map(|_| char::from(rng.range(0x20, 0x7e) as u8)).collect();
        let _ = ArtifactManifest::from_json(&soup);
    }
}

// ---------------------------------------------------------------------------
// APackStore failure injection.
// ---------------------------------------------------------------------------

fn store_temp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apack_finj_{}_{tag}.apackstore", std::process::id()))
}

/// Build a small valid store and return (path, file bytes).
fn build_store(tag: &str) -> (std::path::PathBuf, Vec<u8>) {
    let path = store_temp(tag);
    let values = sample_tensor(20_000, 0xF00D);
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
    let mut w = StoreWriter::create(&path, policy).unwrap();
    w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
    w.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    (path, bytes)
}

/// Truncating the file anywhere in the footer/trailer region must make
/// `open` fail cleanly (no panic, no partial index).
#[test]
fn store_truncated_footer_rejected() {
    let (path, bytes) = build_store("truncfoot");
    // Trailer says where the footer starts; cut at points from inside the
    // footer through the trailer.
    let trailer = &bytes[bytes.len() - TRAILER_BYTES..];
    let footer_offset = u64::from_le_bytes(trailer[0..8].try_into().unwrap()) as usize;
    for keep in [
        footer_offset + 1,
        footer_offset + 10,
        bytes.len() - TRAILER_BYTES,
        bytes.len() - TRAILER_BYTES / 2,
        bytes.len() - 1,
    ] {
        std::fs::write(&path, &bytes[..keep]).unwrap();
        assert!(StoreReader::open(&path).is_err(), "keep={keep}");
    }
    // And degenerate sizes.
    for keep in [0usize, 1, 7, 8, 20] {
        std::fs::write(&path, &bytes[..keep]).unwrap();
        assert!(StoreReader::open(&path).is_err(), "keep={keep}");
    }
    std::fs::remove_file(&path).ok();
}

/// A flipped byte inside any chunk blob must be caught by that chunk's
/// CRC on read — open still succeeds (the footer is intact) but the read
/// errors instead of returning corrupt values.
#[test]
fn store_chunk_bit_flip_caught_by_crc() {
    let (path, bytes) = build_store("bitflip");
    let reader = StoreReader::open(&path).unwrap();
    let chunk1 = reader.meta("t").unwrap().chunks[1];
    drop(reader);
    for delta in [0u64, chunk1.len / 2, chunk1.len - 1] {
        let mut bad = bytes.clone();
        bad[(chunk1.offset + delta) as usize] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        let reader = StoreReader::open(&path).expect("footer is intact");
        let err = reader.get_chunk("t", 1);
        assert!(err.is_err(), "flip at +{delta} must fail CRC");
        // Untouched chunks still read fine.
        assert!(reader.get_chunk("t", 0).is_ok());
        // And whole-store verify reports the corruption too.
        assert!(reader.verify().is_err());
    }
    std::fs::remove_file(&path).ok();
}

/// An index entry pointing past EOF (or into the footer) is rejected at
/// open — before any read could chase the bogus offset.
#[test]
fn store_index_past_eof_rejected() {
    let (path, bytes) = build_store("pasteof");
    let trailer = &bytes[bytes.len() - TRAILER_BYTES..];
    let footer_offset = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
    let footer_len = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
    let footer =
        &bytes[footer_offset as usize..(footer_offset + footer_len) as usize];
    // Default-packed stores carry v2 lane bodies under the APACKST2 magic.
    let index = StoreIndex::from_bytes(footer, 1, StoreFormat::V2).unwrap();

    for bogus_offset in [footer_offset, bytes.len() as u64, u64::MAX - 100] {
        // Rewrite the footer with chunk 2 relocated past the chunk region,
        // with a consistent CRC-carrying trailer (the attack is a hostile
        // index, not a torn write).
        let mut hostile = index.clone();
        hostile.tensors[0].chunks[2].offset = bogus_offset;
        let hostile_footer = StoreIndex::new(hostile.tensors).to_bytes(StoreFormat::V2);
        let mut file = bytes[..footer_offset as usize].to_vec();
        file.extend_from_slice(&hostile_footer);
        file.extend_from_slice(&trailer_bytes(
            footer_offset,
            hostile_footer.len() as u64,
            crc32(&hostile_footer),
            1,
        ));
        std::fs::write(&path, &file).unwrap();
        assert!(
            StoreReader::open(&path).is_err(),
            "chunk offset {bogus_offset:#x} must be rejected"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// Random byte soup and a zeroed trailer never panic the opener.
#[test]
fn store_open_fuzz() {
    let path = store_temp("fuzz");
    let mut rng = Rng64::new(0x5049);
    for _ in 0..50 {
        let n = rng.range(0, 600);
        let soup: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        std::fs::write(&path, &soup).unwrap();
        let _ = StoreReader::open(&path); // must not panic
    }
    // Valid magic + garbage trailer.
    let mut bytes = b"APACKST1".to_vec();
    bytes.extend_from_slice(&[0u8; 64]);
    std::fs::write(&path, &bytes).unwrap();
    assert!(StoreReader::open(&path).is_err());
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Sharded-store failure injection: every broken-directory shape fails
// loudly with a *typed* error, never a silent partial open.
// ---------------------------------------------------------------------------

/// Build a healthy 3-shard store in a temp directory.
fn build_sharded(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("apack_finj_{}_{tag}.apackstore.d", std::process::id()));
    let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
    let mut w = ShardedStoreWriter::create(&dir, 3, policy).unwrap();
    for i in 0..9usize {
        let v = sample_tensor(3000 + 700 * i, 0xBAD0 + i as u64);
        w.add_tensor(&format!("m/layer{i:03}/weights"), 8, &v, TensorKind::Weights)
            .unwrap();
    }
    w.finish().unwrap();
    dir
}

/// A shard file the manifest names but the directory lacks (renamed away,
/// count unchanged) is a typed `ShardMissing` error.
#[test]
fn sharded_missing_shard_file_rejected() {
    let dir = build_sharded("missing");
    std::fs::rename(dir.join(shard_file_name(1)), dir.join(shard_file_name(9))).unwrap();
    match ShardedStoreReader::open(&dir).err() {
        Some(Error::ShardMissing { shard }) => assert_eq!(shard, shard_file_name(1)),
        other => panic!("expected ShardMissing, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A directory whose shard-file count disagrees with the manifest — a
/// deleted shard or a stray extra one — is a typed `ShardCountMismatch`.
#[test]
fn sharded_shard_count_mismatch_rejected() {
    // Deleted shard: 2 files on disk, manifest says 3.
    let dir = build_sharded("delcount");
    std::fs::remove_file(dir.join(shard_file_name(2))).unwrap();
    match StoreHandle::open(&dir).err() {
        Some(Error::ShardCountMismatch { manifest, found }) => {
            assert_eq!((manifest, found), (3, 2));
        }
        other => panic!("expected ShardCountMismatch, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();

    // Stray extra shard file: 4 on disk, manifest says 3.
    let dir = build_sharded("extracount");
    std::fs::copy(dir.join(shard_file_name(0)), dir.join(shard_file_name(3))).unwrap();
    match StoreHandle::open(&dir).err() {
        Some(Error::ShardCountMismatch { manifest, found }) => {
            assert_eq!((manifest, found), (3, 4));
        }
        other => panic!("expected ShardCountMismatch, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Any corruption of the MANIFEST — bit flips anywhere, truncation, byte
/// soup, or absence — is a typed `ManifestCorrupt` error.
#[test]
fn sharded_corrupt_manifest_rejected() {
    let dir = build_sharded("manifest");
    let manifest_path = dir.join(MANIFEST_FILE);
    let good = std::fs::read(&manifest_path).unwrap();

    for i in 0..good.len() {
        let mut bad = good.clone();
        bad[i] ^= 0x08;
        std::fs::write(&manifest_path, &bad).unwrap();
        assert!(
            matches!(ShardedStoreReader::open(&dir), Err(Error::ManifestCorrupt(_))),
            "flip at byte {i}"
        );
    }
    for keep in [0usize, 7, 11, good.len() - 1] {
        std::fs::write(&manifest_path, &good[..keep]).unwrap();
        assert!(matches!(
            ShardedStoreReader::open(&dir),
            Err(Error::ManifestCorrupt(_))
        ));
    }
    let mut rng = Rng64::new(0x3141);
    for _ in 0..50 {
        let n = rng.range(0, 200);
        let soup: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        std::fs::write(&manifest_path, &soup).unwrap();
        let _ = ShardedStoreReader::open(&dir); // must not panic
    }
    std::fs::remove_file(&manifest_path).unwrap();
    assert!(matches!(
        ShardedStoreReader::open(&dir),
        Err(Error::ManifestCorrupt(_))
    ));

    // Restored manifest opens clean again (the shards were never touched).
    std::fs::write(&manifest_path, &good).unwrap();
    assert!(ShardedStoreReader::open(&dir).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// A truncated shard file is caught at open (manifest records each shard's
/// sealed size), and corrupt chunk bytes inside a shard are caught by the
/// per-chunk CRC through the sharded read path.
#[test]
fn sharded_shard_corruption_caught() {
    let dir = build_sharded("shardbody");
    // Truncation: disk size disagrees with the manifest.
    let victim = dir.join(shard_file_name(0));
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() - 3]).unwrap();
    assert!(matches!(
        ShardedStoreReader::open(&dir),
        Err(Error::ManifestCorrupt(_))
    ));
    std::fs::write(&victim, &bytes).unwrap();

    // Same-size chunk corruption: open succeeds, reads + verify fail.
    let reader = ShardedStoreReader::open(&dir).unwrap();
    let name = reader.tensor_names()[0].to_string();
    let home = shard_for_name(&name, 3);
    let chunk0 = reader.meta(&name).unwrap().chunks[0];
    drop(reader);
    let victim = dir.join(shard_file_name(home));
    let mut bad = std::fs::read(&victim).unwrap();
    bad[chunk0.offset as usize + (chunk0.len / 2) as usize] ^= 0x20;
    std::fs::write(&victim, &bad).unwrap();
    let reader = ShardedStoreReader::open(&dir).unwrap();
    assert!(reader.get_tensor(&name).is_err(), "corrupt chunk must fail CRC");
    assert!(reader.verify().is_err(), "verify must report the corruption");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Crash-matrix sweeps (DESIGN.md §14): a deterministic FaultPlan kills the
// writer at every write/fsync/rename boundary of append, seal and compact;
// reopening after any injected crash must land on the last fully-committed
// generation, bit-exactly, on both IO backends.
// ---------------------------------------------------------------------------

fn crash_cleanup(path: &std::path::Path) {
    std::fs::remove_file(path).ok();
    std::fs::remove_file(gen_pointer_path(path)).ok();
    let mut os = path.as_os_str().to_os_string();
    os.push(".gen.tmp");
    std::fs::remove_file(std::path::PathBuf::from(os)).ok();
    let mut os = path.as_os_str().to_os_string();
    os.push(".compact.tmp");
    std::fs::remove_file(std::path::PathBuf::from(os)).ok();
}

/// One live update against the store `build_store` made: replace tensor
/// `t` with fresh values and add tensor `u`, as one committed generation.
fn append_update(path: &std::path::Path, plan: Option<&FaultPlan>) -> Result<(), Error> {
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
    let t = encode_tensor_with(
        &policy,
        BodyConfig::default(),
        "t",
        8,
        &sample_tensor(12_000, 0xD00D),
        TensorKind::Activations,
        None,
        0,
    )?;
    let u = encode_tensor_with(
        &policy,
        BodyConfig::default(),
        "u",
        8,
        &sample_tensor(4_000, 0xCAFE),
        TensorKind::Weights,
        None,
        0,
    )?;
    let mut a = StoreAppender::open_opts(path, plan)?;
    a.append_encoded(t)?;
    a.append_encoded(u)?;
    a.commit()?;
    Ok(())
}

/// Kill the appender at every boundary in turn: reopen must always land on
/// exactly the pre-append generation or the fully-committed new one.
#[test]
fn crash_matrix_append_lands_on_a_committed_generation() {
    let pre_t = sample_tensor(20_000, 0xF00D);
    let post_t = sample_tensor(12_000, 0xD00D);
    let post_u = sample_tensor(4_000, 0xCAFE);
    let mut kill_at = 0u64;
    loop {
        let (path, _) = build_store(&format!("killappend{kill_at}"));
        let plan = FaultPlan::new(FaultConfig {
            kill_at: Some(kill_at),
            ..FaultConfig::default()
        });
        let result = append_update(&path, Some(&plan));
        let killed = plan.kill_fired();
        if killed {
            assert!(result.is_err(), "kill at boundary {kill_at} must surface an error");
        } else {
            result.unwrap_or_else(|e| panic!("clean run past boundary {kill_at}: {e}"));
        }
        for backend in [Backend::Mmap, Backend::File] {
            let r = StoreHandle::open_with(&path, backend, 0)
                .unwrap_or_else(|e| panic!("kill {kill_at}: store must stay openable: {e}"));
            match r.generation() {
                0 => {
                    assert!(killed, "only a killed run may stay on generation 0");
                    assert_eq!(r.get_tensor("t").unwrap(), pre_t, "kill {kill_at}");
                    assert!(r.meta("u").is_err(), "kill {kill_at}: u must not exist yet");
                }
                1 => {
                    assert_eq!(r.get_tensor("t").unwrap(), post_t, "kill {kill_at}");
                    assert_eq!(r.get_tensor("u").unwrap(), post_u, "kill {kill_at}");
                }
                g => panic!("kill {kill_at}: unexpected generation {g}"),
            }
            if !killed {
                assert_eq!(r.generation(), 1, "a clean append must commit generation 1");
            }
        }
        crash_cleanup(&path);
        if !killed {
            break;
        }
        kill_at += 1;
    }
    assert!(kill_at > 5, "lattice must cover several boundaries, saw {kill_at}");
}

/// Kill compaction at every boundary: the store stays openable at every
/// crash point and always serves the same live content (compaction never
/// changes what is live, only where it sits).
#[test]
fn crash_matrix_compact_preserves_live_content() {
    let post_t = sample_tensor(12_000, 0xD00D);
    let post_u = sample_tensor(4_000, 0xCAFE);
    let mut kill_at = 0u64;
    loop {
        let (path, _) = build_store(&format!("killcompact{kill_at}"));
        append_update(&path, None).unwrap();
        let plan = FaultPlan::new(FaultConfig {
            kill_at: Some(kill_at),
            ..FaultConfig::default()
        });
        let result = compact_store(&path, Some(&plan));
        let killed = plan.kill_fired();
        if !killed {
            let summary =
                result.unwrap_or_else(|e| panic!("clean run past boundary {kill_at}: {e}"));
            assert_eq!(summary.generation, 2);
        }
        for backend in [Backend::Mmap, Backend::File] {
            let r = StoreHandle::open_with(&path, backend, 0).unwrap_or_else(|e| {
                panic!("kill {kill_at}: compaction crash must leave the store openable: {e}")
            });
            assert_eq!(r.get_tensor("t").unwrap(), post_t, "kill {kill_at}");
            assert_eq!(r.get_tensor("u").unwrap(), post_u, "kill {kill_at}");
            assert!(
                r.generation() == 1 || r.generation() == 2,
                "kill {kill_at}: generation {} is neither source nor compacted",
                r.generation()
            );
        }
        crash_cleanup(&path);
        if !killed {
            break;
        }
        kill_at += 1;
    }
    assert!(kill_at > 4, "lattice must cover several boundaries, saw {kill_at}");
}

/// Sharded crash matrix: the MANIFEST flip is the commit point — a crash
/// anywhere in a multi-shard append (replace one tensor, tombstone
/// another) leaves either the complete old state or the complete new one,
/// never a mix.
#[test]
fn crash_matrix_sharded_append_commits_atomically() {
    let old_l0 = sample_tensor(3000, 0xBAD0);
    let new_l0 = sample_tensor(5_000, 0xD1CE);
    let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
    let mut kill_at = 0u64;
    loop {
        let dir = build_sharded(&format!("killshard{kill_at}"));
        let plan = FaultPlan::new(FaultConfig {
            kill_at: Some(kill_at),
            ..FaultConfig::default()
        });
        let result = (|| -> Result<(), Error> {
            let t = encode_tensor_with(
                &policy,
                BodyConfig::default(),
                "m/layer000/weights",
                8,
                &new_l0,
                TensorKind::Weights,
                None,
                0,
            )?;
            let mut a = ShardedStoreAppender::open_opts(&dir, Some(&plan))?;
            a.append_encoded(t)?;
            assert!(a.tombstone("m/layer001/weights"));
            a.commit()?;
            Ok(())
        })();
        let killed = plan.kill_fired();
        if !killed {
            result.unwrap_or_else(|e| panic!("clean run past boundary {kill_at}: {e}"));
        }
        let r = StoreHandle::open(&dir)
            .unwrap_or_else(|e| panic!("kill {kill_at}: sharded store must reopen: {e}"));
        if r.generation() == 0 {
            assert!(killed, "only a killed run may stay on generation 0");
            assert_eq!(r.get_tensor("m/layer000/weights").unwrap(), old_l0, "kill {kill_at}");
            assert!(
                r.meta("m/layer001/weights").is_ok(),
                "kill {kill_at}: old state must keep the tombstoned tensor"
            );
        } else {
            assert_eq!(r.get_tensor("m/layer000/weights").unwrap(), new_l0, "kill {kill_at}");
            assert!(
                r.meta("m/layer001/weights").is_err(),
                "kill {kill_at}: new state must have dropped the tombstoned tensor"
            );
        }
        // Untouched shards serve their tensors in either state.
        assert_eq!(
            r.get_tensor("m/layer002/weights").unwrap(),
            sample_tensor(3000 + 700 * 2, 0xBAD2),
            "kill {kill_at}"
        );
        std::fs::remove_dir_all(&dir).ok();
        if !killed {
            break;
        }
        kill_at += 1;
    }
    assert!(kill_at > 4, "lattice must cover several boundaries, saw {kill_at}");
}

/// Injected read faults surface as *transient* errors: within the budget
/// the store-level retry loop absorbs them (both backends), and with an
/// unbounded fault rate the typed `Transient` error reaches the caller.
#[test]
fn injected_read_faults_are_transient_and_bounded() {
    let (path, _) = build_store("injreads");
    let expect = sample_tensor(20_000, 0xF00D);
    for backend in [Backend::Mmap, Backend::File] {
        // Budget below the per-read retry allowance: every read eventually
        // succeeds and the retries are visible in the stats.
        let plan = FaultPlan::new(FaultConfig {
            read_error_rate: 1.0,
            max_injected_errors: 3,
            ..FaultConfig::default()
        });
        let r = StoreHandle::open_with_plan(&path, backend, 0, Some(&plan)).unwrap();
        assert_eq!(r.get_tensor("t").unwrap(), expect);
        assert!(plan.injected_errors() >= 1, "{backend:?}: no faults injected");
        assert!(
            r.stats().transient_retries >= 1,
            "{backend:?}: retries must show in the stats"
        );
    }
    // Unbounded rate-1.0 injection exhausts the retry loop.
    let plan = FaultPlan::new(FaultConfig { read_error_rate: 1.0, ..FaultConfig::default() });
    let r = StoreHandle::open_with_plan(&path, Backend::File, 0, Some(&plan)).unwrap();
    let err = r.get_chunk("t", 0).unwrap_err();
    assert!(err.is_transient(), "expected a transient error, got {err}");
    crash_cleanup(&path);
}

/// A corrupted generation-pointer sidecar falls back to the classic
/// exact-EOF open (which still lands on the committed generation, because
/// seal truncates the file to the committed length) and `verify`
/// classifies the damage with its own exit code instead of bailing.
#[test]
fn corrupt_generation_pointer_falls_back_and_classifies() {
    let (path, _) = build_store("badptr");
    append_update(&path, None).unwrap();
    let ptr = gen_pointer_path(&path);
    let good = std::fs::read(&ptr).unwrap();
    let mut bad = good.clone();
    bad[4] ^= 0xFF;
    std::fs::write(&ptr, &bad).unwrap();

    let r = StoreHandle::open(&path).unwrap();
    assert_eq!(r.generation(), 1, "classic fallback still lands on the committed gen");
    assert_eq!(r.get_tensor("u").unwrap(), sample_tensor(4_000, 0xCAFE));

    let report = verify_store(&path, Backend::Mmap);
    assert!(!report.is_clean());
    assert!(report
        .issues
        .iter()
        .any(|i| i.class == CorruptionClass::GenerationPointer));
    assert_eq!(report.worst_class().unwrap().exit_code(), 14);

    // Restoring the pointer restores a clean report.
    std::fs::write(&ptr, &good).unwrap();
    assert!(verify_store(&path, Backend::Mmap).is_clean());
    crash_cleanup(&path);
}

/// Encoding a value outside the table's coverage errors cleanly.
#[test]
fn out_of_coverage_values_error() {
    let values = vec![0u32; 100]; // only zeros occur
    let t = table_for_tensor(8, &values, TensorKind::Weights).unwrap();
    // Weights tablegen zeroes out absent ranges; find an uncovered value.
    let uncovered = (0u32..=255).find(|&v| {
        let idx = t.lookup(v).unwrap();
        t.rows()[idx].hi_cnt == t.lo_cnt(idx)
    });
    if let Some(v) = uncovered {
        let mut enc = ApackEncoder::new(&t);
        let mut s = apack_repro::apack::bitstream::BitWriter::new();
        let mut o = apack_repro::apack::bitstream::BitWriter::new();
        assert!(enc.encode_value(v, &mut s, &mut o).is_err());
    }
}
