//! Cross-module integration tests: the zoo → trace → codec → simulator →
//! eval pipeline, container serialization across the coordinator, and the
//! engine pool over real compressed shards.

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::{Coordinator, EnginePool, PartitionPolicy, ShardedContainer};
use apack_repro::eval::study::{CompressionStudy, Scheme};
use apack_repro::eval::{fig5, fig7, fig8};
use apack_repro::models::trace::ModelTrace;
use apack_repro::models::zoo::{all_models, model_by_name};
use apack_repro::simulator::accelerator::{AcceleratorConfig, AcceleratorSim, TrafficScaling};
use apack_repro::simulator::engine::EngineArrayConfig;

#[test]
fn zoo_trace_compress_simulate_pipeline() {
    // One model end to end through every subsystem except PJRT.
    let cfg = model_by_name("ncf").unwrap();
    let trace = ModelTrace::synthesize(&cfg, 4096, 3, 7);
    let mut coord = Coordinator::new(PartitionPolicy::default());

    let mut ratios = Vec::new();
    for l in trace.layers.iter().take(3) {
        let sc = coord.compress(cfg.bits, &l.weights, TensorKind::Weights, None).unwrap();
        assert_eq!(coord.decompress(&sc).unwrap(), l.weights);
        ratios.push(sc.compression_ratio());
    }
    assert!(ratios.iter().any(|&r| r > 1.0), "some layer must compress: {ratios:?}");

    // Feed measured ratios into the accelerator model.
    let sim = AcceleratorSim::new(AcceleratorConfig::paper());
    let base = sim.simulate_model(&cfg, &|_| TrafficScaling::NONE);
    let comp = sim.simulate_model(&cfg, &|_| TrafficScaling {
        weights: 1.0 / ratios[0],
        activations: 0.5,
    });
    assert!(
        AcceleratorSim::total_time(&comp) <= AcceleratorSim::total_time(&base) + 1e-12
    );
}

#[test]
fn study_consistency_across_figures() {
    // Figs 5/7/8 must agree on the underlying study data.
    let models = vec![model_by_name("ncf").unwrap(), model_by_name("bilstm").unwrap()];
    let study = CompressionStudy::run(
        &models,
        &[Scheme::Baseline, Scheme::ShapeShifter, Scheme::Apack],
    );
    // Renderers run without panicking and contain each model.
    for text in
        [fig5::render(&study), fig7::render(&study), fig8::render(&study)]
    {
        assert!(text.contains("ncf"));
        assert!(text.contains("bilstm"));
    }
    // Fig 7 speedups derive from Fig 5 compressions: a model whose APack
    // norm is lower must not be slower with APack than baseline.
    for m in &models {
        let base = fig7::latency_s(&study, m, Scheme::Baseline);
        let ap = fig7::latency_s(&study, m, Scheme::Apack);
        assert!(ap <= base + 1e-12, "{}", m.name);
    }
}

#[test]
fn sharded_container_binary_roundtrip() {
    let values: Vec<u32> = (0..40_000u32).map(|i| (i * 2654435761) >> 26).collect();
    let mut coord = Coordinator::new(PartitionPolicy { substreams: 8, min_per_stream: 512 });
    let sc = coord.compress(8, &values, TensorKind::Weights, None).unwrap();
    let bytes = sc.to_bytes();
    let sc2 = ShardedContainer::from_bytes(&bytes).unwrap();
    assert_eq!(sc2.n_values, sc.n_values);
    assert_eq!(sc2.shards.len(), sc.shards.len());
    assert_eq!(coord.decompress(&sc2).unwrap(), values);
    // Corruption detected.
    let mut bad = bytes.clone();
    bad.truncate(bad.len() / 2);
    assert!(ShardedContainer::from_bytes(&bad).is_err());
}

#[test]
fn engine_pool_matches_direct_decode() {
    let values: Vec<u32> = (0..50_000u32).map(|i| if i % 3 == 0 { 0 } else { i % 256 }).collect();
    let mut coord = Coordinator::new(PartitionPolicy { substreams: 16, min_per_stream: 256 });
    let sc = coord.compress(8, &values, TensorKind::Activations, None).unwrap();
    let direct = coord.decompress(&sc).unwrap();
    let pool = EnginePool::new(6, 16);
    let pooled = pool.decode_shards(&sc.shards).unwrap();
    assert_eq!(direct, pooled);
    assert_eq!(pooled, values);
}

#[test]
fn paper_claims_hold_on_zoo_subset() {
    // Fast sanity on the headline claims, on a 4-model subset:
    // APack always reduces traffic and beats SS / RLE / RLEZ.
    let models: Vec<_> = ["resnet18", "mobilenet_v1", "q8bert", "googlenet_eyeriss"]
        .iter()
        .map(|n| model_by_name(n).unwrap())
        .collect();
    let study = CompressionStudy::run(&models, &Scheme::ALL);
    for m in &models {
        let ap = study.get(m.name, Scheme::Apack).unwrap();
        assert!(ap.weights_norm < 1.0, "{}: {}", m.name, ap.weights_norm);
        for s in [Scheme::Rle, Scheme::Rlez, Scheme::ShapeShifter] {
            let o = study.get(m.name, s).unwrap();
            assert!(
                ap.weights_norm <= o.weights_norm + 1e-9,
                "{}: APack {} vs {s:?} {}",
                m.name,
                ap.weights_norm,
                o.weights_norm
            );
        }
    }
}

#[test]
fn engine_array_bandwidth_covers_dram() {
    // §V-B sizing argument: 64 engines at 1 GHz sustain the dual-channel
    // DDR4-3200 peak for 8-bit streams.
    let arr = EngineArrayConfig::paper_64();
    let sim = AcceleratorSim::new(AcceleratorConfig::paper());
    assert!(arr.throughput_bytes_per_s(8) >= sim.cfg.dram.peak_bandwidth());
}

#[test]
fn zoo_is_complete_and_consistent() {
    let models = all_models();
    assert_eq!(models.len(), 24);
    let perf: Vec<_> = models.iter().filter(|m| m.in_perf_study).collect();
    assert!(perf.len() >= 12, "perf study subset too small: {}", perf.len());
}

#[test]
fn hot_path_harness_bit_exact_and_emits_json() {
    // The codec hot-path harness on a tier-1-sized workload: the harness
    // itself asserts every decode configuration (per-value and block, all
    // three resolvers, and the sharded coordinator) bit-exact against the
    // encoder input, so this test is the build-profile-portable version of
    // the bench's regression gate. It also (re)writes the machine-readable
    // BENCH_codec_hot_path.json at the package root; `cargo bench --bench
    // codec_hot_path` overwrites it with release-profile numbers.
    let report = apack_repro::eval::hot_path::run(
        &apack_repro::eval::hot_path::HotPathConfig::tiny(),
    );
    for path in ["decode/per-value", "decode/block"] {
        for mode in ["RowScan", "Division", "Lut"] {
            let name = format!("{path}/{mode}");
            let e = report.entry(&name).unwrap_or_else(|| panic!("missing entry {name}"));
            assert!(e.values_per_s > 0.0, "{name} measured nothing");
        }
    }
    assert!(report.entry("coordinator/decode/16-substream").is_some());
    assert!(report.speedup_block_lut_vs_per_value_rowscan > 0.0);
    // Emit the JSON artifact — but never clobber release-profile numbers a
    // `cargo bench` run already produced with this debug-profile run.
    let path = std::path::Path::new(apack_repro::eval::hot_path::REPORT_FILE);
    let release_numbers_present = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| apack_repro::util::json::Json::parse(&s).ok())
        .and_then(|j| j.get("profile").and_then(|p| p.as_str().map(String::from)))
        .is_some_and(|p| p == "release");
    if !release_numbers_present {
        report.write_json(path).expect("write BENCH_codec_hot_path.json");
    }
}

#[test]
fn ingest_harness_equivalences_hold_and_emit_json() {
    // The write-path mirror of the hot-path test above: the ingest harness
    // asserts — before timing anything — that the incremental tablegen
    // search matches the seed search byte-for-byte, the block encoder
    // matches the per-value reference bit-for-bit (and round-trips), and
    // the pipelined packer writes the exact serial bytes (and the packed
    // store verifies). It also (re)writes BENCH_store_pack.json at the
    // package root; `cargo bench --bench store_pack` overwrites it with
    // release-profile numbers.
    let report =
        apack_repro::eval::ingest::run(&apack_repro::eval::ingest::IngestConfig::tiny());
    for name in [
        "tablegen/seed/8b-relu",
        "tablegen/incremental/8b-relu",
        "encode/per-value/8b-relu",
        "encode/block/8b-relu",
        "pack/serial",
        "pack/pipelined",
    ] {
        let e = report.entry(name).unwrap_or_else(|| panic!("missing entry {name}"));
        assert!(e.values_per_s > 0.0, "{name} measured nothing");
    }
    assert!(report.speedup_block_vs_per_value_encode > 0.0);
    assert!(report.speedup_incremental_vs_seed_tablegen > 0.0);
    assert!(report.speedup_pipelined_vs_serial_pack > 0.0);
    // Emit the JSON artifact — but never clobber release-profile numbers a
    // `cargo bench` run already produced with this debug-profile run.
    let path = std::path::Path::new(apack_repro::eval::ingest::REPORT_FILE);
    let release_numbers_present = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| apack_repro::util::json::Json::parse(&s).ok())
        .and_then(|j| j.get("profile").and_then(|p| p.as_str().map(String::from)))
        .is_some_and(|p| p == "release");
    if !release_numbers_present {
        report.write_json(path).expect("write BENCH_store_pack.json");
    }
}
