//! Property-based tests (in-tree randomized harness standing in for
//! proptest, which is unavailable offline). Each property runs many
//! seeded random cases; a failing seed reproduces deterministically.
//!
//! Properties map to DESIGN.md §6 invariants 1–6.

use apack_repro::apack::bitstream::{BitReader, BitWriter};
use apack_repro::apack::decoder::{ApackDecoder, ResolveMode};
use apack_repro::apack::encoder::ApackEncoder;
use apack_repro::apack::tablegen::{
    estimate_bits, generate_table, TableGenConfig, TensorKind, METADATA_BITS,
};
use apack_repro::apack::{Histogram, SymbolTable, NUM_ROWS, PROB_MAX};
use apack_repro::baselines::{
    rle_decode, rle_encode, rlez_decode, rlez_encode, ss_decode, ss_encode, ShapeShifterConfig,
};
use apack_repro::coordinator::{Coordinator, PartitionPolicy};
use apack_repro::store::{StoreReader, StoreWriter};
use apack_repro::util::Rng64;

/// Random valid table: random strictly-increasing v_mins + random counts
/// with every occurring-value row non-empty.
fn random_table(rng: &mut Rng64, bits: u32) -> SymbolTable {
    let max = SymbolTable::value_max_for(bits);
    // Choose 15 distinct boundaries in (0, max].
    let mut bounds = std::collections::BTreeSet::new();
    while bounds.len() < NUM_ROWS - 1 {
        bounds.insert(rng.range(1, max as usize) as u32);
    }
    let mut v_mins = [0u32; NUM_ROWS];
    for (i, b) in bounds.into_iter().enumerate() {
        v_mins[i + 1] = b;
    }
    // Random positive count weights, normalized to PROB_MAX with floor 1.
    let mut weights = [0u64; NUM_ROWS];
    for w in weights.iter_mut() {
        *w = 1 + rng.below(1000);
    }
    let total: u64 = weights.iter().sum();
    let mut hi_cnts = [0u16; NUM_ROWS];
    let mut acc = 0u64;
    let mut assigned = 0u64;
    for i in 0..NUM_ROWS {
        let share = (weights[i] * (PROB_MAX as u64 - (NUM_ROWS as u64 - assigned)) / total)
            .max(1)
            .min(PROB_MAX as u64 - acc - (NUM_ROWS as u64 - 1 - i as u64));
        acc += share;
        assigned += 1;
        hi_cnts[i] = acc as u16;
    }
    hi_cnts[NUM_ROWS - 1] = PROB_MAX;
    SymbolTable::new(bits, v_mins, hi_cnts).expect("constructed table is valid")
}

fn random_tensor(rng: &mut Rng64, bits: u32, n: usize) -> Vec<u32> {
    let max = (1u64 << bits) as u64;
    // Mix of skew shapes to stress different symbol sequences.
    (0..n)
        .map(|_| match rng.below(4) {
            0 => 0,
            1 => (max - 1 - rng.below(max.min(4))) as u32,
            2 => rng.below(max.min(8)) as u32,
            _ => rng.below(max) as u32,
        })
        .collect()
}

/// Invariant 1: decode(encode(t)) == t for random tensors × random valid
/// tables (every row has nonzero count by construction).
#[test]
fn prop_roundtrip_random_tables() {
    for seed in 0..40u64 {
        let mut rng = Rng64::new(seed);
        let bits = [4u32, 8, 8, 8, 16][rng.below(5) as usize];
        let table = random_table(&mut rng, bits);
        let n = rng.range(0, 5000);
        let values = random_tensor(&mut rng, bits, n);
        let (sym, sb, ofs, ob) =
            ApackEncoder::encode_all(&table, &values).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got = ApackDecoder::decode_all(&table, BitReader::new(&sym, sb), &mut ofs_r, n)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(got, values, "seed {seed}");
    }
}

/// Invariant 1 with generated tables (tablegen output on the tensor's own
/// histogram).
#[test]
fn prop_roundtrip_generated_tables() {
    for seed in 0..25u64 {
        let mut rng = Rng64::new(0x7AB1E + seed);
        let bits = if rng.chance(0.3) { 4 } else { 8 };
        let n = rng.range(1, 20_000);
        let values = random_tensor(&mut rng, bits, n);
        let hist = Histogram::from_values(bits, &values);
        let kind =
            if rng.chance(0.5) { TensorKind::Weights } else { TensorKind::Activations };
        let table = generate_table(&hist, kind, &TableGenConfig::for_bits(bits)).unwrap();
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got =
            ApackDecoder::decode_all(&table, BitReader::new(&sym, sb), &mut ofs_r, n).unwrap();
        assert_eq!(got, values, "seed {seed}");
    }
}

/// Invariant 2: tablegen output is always structurally valid and, for
/// activations, fully covering.
#[test]
fn prop_tablegen_validity() {
    for seed in 0..25u64 {
        let mut rng = Rng64::new(0xBEEF + seed);
        let n = rng.range(16, 30_000);
        let values = random_tensor(&mut rng, 8, n);
        let hist = Histogram::from_values(8, &values);
        let t =
            generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
        assert_eq!(t.rows()[NUM_ROWS - 1].hi_cnt, PROB_MAX);
        assert_eq!(t.rows()[NUM_ROWS - 1].v_max, 255);
        assert_eq!(t.rows()[0].v_min, 0);
        for i in 0..NUM_ROWS {
            assert!(t.rows()[i].hi_cnt > t.lo_cnt(i), "seed {seed} row {i} empty");
            assert!(t.rows()[i].v_min <= t.rows()[i].v_max);
        }
    }
}

/// Decode a stream per-value in one mode, recording the decoded prefix and
/// the position of the first `CorruptStream` error (if any).
fn per_value_outcome(
    table: &SymbolTable,
    sym: &[u8],
    sb: usize,
    ofs: &[u8],
    ob: usize,
    n: usize,
    mode: ResolveMode,
) -> (Vec<u32>, Option<usize>) {
    let mut dec =
        ApackDecoder::new(table, BitReader::new(sym, sb)).unwrap().with_mode(mode);
    let mut ofs_r = BitReader::new(ofs, ob);
    let mut out = Vec::new();
    for _ in 0..n {
        match dec.decode_value(&mut ofs_r) {
            Ok(v) => out.push(v),
            Err(apack_repro::Error::CorruptStream { position }) => {
                return (out, Some(position))
            }
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    (out, None)
}

/// Same outcome through the block `decode_into` path.
fn block_outcome(
    table: &SymbolTable,
    sym: &[u8],
    sb: usize,
    ofs: &[u8],
    ob: usize,
    n: usize,
    mode: ResolveMode,
) -> (Vec<u32>, Option<usize>) {
    let mut dec =
        ApackDecoder::new(table, BitReader::new(sym, sb)).unwrap().with_mode(mode);
    let mut ofs_r = BitReader::new(ofs, ob);
    let mut out = vec![0u32; n];
    match dec.decode_into(&mut out, &mut ofs_r) {
        Ok(()) => (out, None),
        Err(apack_repro::Error::CorruptStream { position }) => {
            out.truncate(position);
            (out, Some(position))
        }
        Err(e) => panic!("unexpected error {e}"),
    }
}

/// Invariant 3: the three decoder symbol-resolution circuits (`RowScan`,
/// `Division`, `Lut`) and both decode granularities (per-value reference,
/// block `decode_into`) agree on every step of every stream — decoded
/// prefix AND `CorruptStream` position, on clean, bit-flipped and
/// truncated streams alike.
#[test]
fn prop_resolver_equivalence() {
    for seed in 0..15u64 {
        let mut rng = Rng64::new(0xD1CE + seed);
        let table = random_table(&mut rng, 8);
        let values = random_tensor(&mut rng, 8, 3000);
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
        let n = values.len();

        // Clean, symbol-corrupted, offset-corrupted and offset-truncated
        // variants of the same stream.
        let mut sym_flip = sym.clone();
        sym_flip[rng.below(sym.len() as u64) as usize] ^= 1 << rng.below(8);
        let mut ofs_flip = ofs.clone();
        if !ofs_flip.is_empty() {
            ofs_flip[rng.below(ofs_flip.len() as u64) as usize] ^= 1 << rng.below(8);
        }
        let cases: [(&str, &[u8], usize, &[u8], usize); 4] = [
            ("clean", &sym, sb, &ofs, ob),
            ("sym-flip", &sym_flip, sb, &ofs, ob),
            ("ofs-flip", &sym, sb, &ofs_flip, ob),
            ("ofs-trunc", &sym, sb, &ofs, ob / 2),
        ];
        for (tag, s, s_bits, o, o_bits) in cases {
            let reference =
                per_value_outcome(&table, s, s_bits, o, o_bits, n, ResolveMode::RowScan);
            if tag == "clean" {
                assert_eq!(reference, (values.clone(), None), "seed {seed}");
            }
            for mode in ResolveMode::ALL {
                let pv = per_value_outcome(&table, s, s_bits, o, o_bits, n, mode);
                assert_eq!(pv, reference, "seed {seed} {tag} per-value {mode:?}");
                let blk = block_outcome(&table, s, s_bits, o, o_bits, n, mode);
                assert_eq!(blk, reference, "seed {seed} {tag} block {mode:?}");
            }
        }
    }
}

/// Invariant 3 continued: block `decode_into` is bit-exact vs. per-value
/// `decode_value` on every `ValueProfile` (the distribution shapes the
/// symbol mix, exercising different resolver rows and renorm patterns) and
/// on truncated/corrupted streams derived from each.
#[test]
fn prop_block_decode_matches_per_value_on_all_profiles() {
    use apack_repro::models::distributions::ValueProfile;
    let profiles = [
        ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.01 },
        ValueProfile::Sparse { sparsity: 0.6, q: 0.85 },
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ValueProfile::Uniform,
    ];
    for (pi, profile) in profiles.iter().enumerate() {
        let values = profile.sample(8, 20_000, 0xB10C + pi as u64);
        let hist = Histogram::from_values(8, &values);
        let table =
            generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
        let n = values.len();
        let mut sym_bad = sym.clone();
        sym_bad[sym.len() / 3] ^= 0x24;
        let cases: [(&str, &[u8], usize, &[u8], usize); 3] = [
            ("clean", &sym, sb, &ofs, ob),
            ("sym-corrupt", &sym_bad, sb, &ofs, ob),
            ("ofs-trunc", &sym, sb, &ofs, ob / 3),
        ];
        for (tag, s, s_bits, o, o_bits) in cases {
            for mode in ResolveMode::ALL {
                let pv = per_value_outcome(&table, s, s_bits, o, o_bits, n, mode);
                let blk = block_outcome(&table, s, s_bits, o, o_bits, n, mode);
                assert_eq!(blk, pv, "profile {pi} {tag} {mode:?}");
                if tag == "clean" {
                    assert_eq!(pv, (values.clone(), None), "profile {pi} {mode:?}");
                }
            }
        }
    }
}

/// Ingest invariant (DESIGN.md §9): the block encoder `encode_into` is
/// bit-identical to the per-value `encode_value` loop — symbol *and*
/// offset streams, including the flush tail — across every `ValueProfile`
/// and 4/8/16-bit widths, and both match the bit-serial hardware
/// reference model exactly.
#[test]
fn prop_block_encoder_bit_identical_to_per_value_and_bitserial() {
    use apack_repro::apack::bitserial::BitSerialEncoder;
    use apack_repro::models::distributions::ValueProfile;
    let profiles = [
        ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.01 },
        ValueProfile::Sparse { sparsity: 0.6, q: 0.85 },
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ValueProfile::Uniform,
    ];
    for bits in [4u32, 8, 16] {
        for (pi, profile) in profiles.iter().enumerate() {
            let n = if bits == 16 { 4000 } else { 8000 };
            let values = profile.sample(bits, n, 0xE4C0_DE + pi as u64 + bits as u64);
            let hist = Histogram::from_values(bits, &values);
            let table =
                generate_table(&hist, TensorKind::Activations, &TableGenConfig::for_bits(bits))
                    .unwrap();

            // Per-value reference (with flush).
            let mut enc = ApackEncoder::new(&table);
            let (mut s, mut o) = (BitWriter::new(), BitWriter::new());
            for &v in &values {
                enc.encode_value(v, &mut s, &mut o).unwrap();
            }
            enc.finish(&mut s);
            let per_value = (s.finish(), o.finish());

            // Block fast path (encode_all delegates to encode_into).
            let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
            assert_eq!(
                ((sym.clone(), sb), (ofs.clone(), ob)),
                per_value,
                "bits {bits} profile {pi}: block vs per-value"
            );

            // Bit-serial hardware reference model.
            let mut ref_enc = BitSerialEncoder::new(&table);
            let (mut rs, mut ro) = (BitWriter::new(), BitWriter::new());
            for &v in &values {
                ref_enc.encode_value(v, &mut rs, &mut ro).unwrap();
            }
            ref_enc.finish(&mut rs);
            assert_eq!(
                ((sym.clone(), sb), (ofs.clone(), ob)),
                (rs.finish(), ro.finish()),
                "bits {bits} profile {pi}: block vs bit-serial reference"
            );

            // And the stream decodes back to the input.
            let mut ofs_r = BitReader::new(&ofs, ob);
            let got = ApackDecoder::decode_all(&table, BitReader::new(&sym, sb), &mut ofs_r, n)
                .unwrap();
            assert_eq!(got, values, "bits {bits} profile {pi}: roundtrip");
        }
    }
}

/// Ingest invariant (DESIGN.md §9): the incremental tablegen search
/// produces byte-identical tables to the seed (full-recompute) search on
/// real zoo histograms — weights and pooled activation profiles — plus
/// random tensors.
#[test]
fn prop_incremental_tablegen_matches_seed() {
    use apack_repro::apack::tablegen::generate_table_seed;
    use apack_repro::models::trace::ModelTrace;
    use apack_repro::models::zoo::model_by_name;

    // Zoo histograms: a couple of models, all layers, both tensor kinds.
    for name in ["ncf", "bilstm"] {
        let cfg = model_by_name(name).unwrap();
        let trace = ModelTrace::synthesize(&cfg, 2048, 3, 0xA9AC_2022);
        for l in &trace.layers {
            let whist = Histogram::from_values(l.bits, &l.weights);
            let tg = TableGenConfig::for_bits(l.bits);
            let inc = generate_table(&whist, TensorKind::Weights, &tg).unwrap();
            let seed = generate_table_seed(&whist, TensorKind::Weights, &tg).unwrap();
            assert_eq!(inc.to_bytes(), seed.to_bytes(), "{name} layer {} weights", l.layer_idx);
            if !l.act_profile_samples.is_empty() {
                let ahist = Histogram::from_values(l.bits, &l.act_profile_samples);
                let inc = generate_table(&ahist, TensorKind::Activations, &tg).unwrap();
                let seed = generate_table_seed(&ahist, TensorKind::Activations, &tg).unwrap();
                assert_eq!(
                    inc.to_bytes(),
                    seed.to_bytes(),
                    "{name} layer {} activations",
                    l.layer_idx
                );
            }
        }
    }

    // Random tensors across widths and kinds (16-bit pairs are covered
    // once in the tablegen unit tests — the coarse-stride seed search is
    // too slow to repeat per random case in a debug build).
    for seed in 0..10u64 {
        let mut rng = Rng64::new(0x7AB_5EED + seed);
        let bits = [4u32, 8, 8, 8][rng.below(4) as usize];
        let n = rng.range(16, 20_000);
        let values = random_tensor(&mut rng, bits, n);
        let hist = Histogram::from_values(bits, &values);
        let kind = if rng.chance(0.5) { TensorKind::Weights } else { TensorKind::Activations };
        let tg = TableGenConfig::for_bits(bits);
        let inc = generate_table(&hist, kind, &tg).unwrap();
        let sd = generate_table_seed(&hist, kind, &tg).unwrap();
        assert_eq!(inc.to_bytes(), sd.to_bytes(), "seed {seed}");
    }
}

/// Chunk-body v2 invariant (DESIGN.md §11): for every `ValueProfile` ×
/// 4/8/16-bit widths, a v2 lane body decodes bit-exactly — through both
/// the struct-of-arrays decoder and the threaded lane-per-sub-slice
/// decoder — to the same values as the v1 single-stream body over the
/// same symbol table, and both match the original tensor.
#[test]
fn prop_body_v2_bit_exact_across_profiles_and_widths() {
    use apack_repro::apack::container::{encode_body, BodyView};
    use apack_repro::apack::lanes::{encode_body_v2, lane_count, BodyV2View};
    use apack_repro::models::distributions::ValueProfile;
    let profiles = [
        ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.01 },
        ValueProfile::Sparse { sparsity: 0.6, q: 0.85 },
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ValueProfile::Uniform,
    ];
    for bits in [4u32, 8, 16] {
        for (pi, profile) in profiles.iter().enumerate() {
            let n = if bits == 16 { 8192 } else { 20_000 };
            let values = profile.sample(bits, n, 0x1A9E_5 + pi as u64 + bits as u64);
            let hist = Histogram::from_values(bits, &values);
            let table =
                generate_table(&hist, TensorKind::Activations, &TableGenConfig::for_bits(bits))
                    .unwrap();

            let v1 = encode_body(&table, &values).unwrap();
            let mut from_v1 = vec![0u32; n];
            BodyView::parse(&v1).unwrap().decode_into(&table, &mut from_v1).unwrap();
            assert_eq!(from_v1, values, "bits {bits} profile {pi}: v1 body");

            let v2 = encode_body_v2(&table, &values, 16).unwrap();
            let view = BodyV2View::parse(&v2).unwrap();
            assert_eq!(
                view.lanes(),
                lane_count(n, 16) as usize,
                "bits {bits} profile {pi}: directory lane count"
            );
            let mut soa = vec![0u32; n];
            view.decode_into(&table, &mut soa).unwrap();
            assert_eq!(soa, from_v1, "bits {bits} profile {pi}: SoA vs v1");
            let mut threaded = vec![0u32; n];
            view.decode_into_threaded(&table, &mut threaded, 0).unwrap();
            assert_eq!(threaded, from_v1, "bits {bits} profile {pi}: threaded vs v1");
        }
    }
}

/// SIMD kernel invariant (DESIGN.md §13): the lane-parallel SIMD decode
/// kernel is bit-identical to the scalar SoA loop on clean bodies across
/// every `ValueProfile` × 4/8/16-bit widths × the lane sweep up to 64
/// lanes (the workload is sized so 64 requested lanes stay effective),
/// through both the single-threaded and the threaded decode paths.
#[test]
fn prop_simd_kernel_bit_identical_to_scalar_across_profiles_widths_lanes() {
    use apack_repro::apack::lanes::{encode_body_v2, lane_count, BodyV2View};
    use apack_repro::apack::DecodeKernel;
    use apack_repro::models::distributions::ValueProfile;
    let profiles = [
        ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.01 },
        ValueProfile::Sparse { sparsity: 0.6, q: 0.85 },
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ValueProfile::Uniform,
    ];
    // 64 lanes need >= 64 * MIN_VALUES_PER_LANE (65536) values to avoid
    // degrading to a smaller power of two.
    let n = 66_000usize;
    for bits in [4u32, 8, 16] {
        for (pi, profile) in profiles.iter().enumerate() {
            let values = profile.sample(bits, n, 0x51D_0 + pi as u64 + bits as u64);
            let hist = Histogram::from_values(bits, &values);
            let table =
                generate_table(&hist, TensorKind::Activations, &TableGenConfig::for_bits(bits))
                    .unwrap();
            for req in [1u8, 4, 16, 64] {
                let body = encode_body_v2(&table, &values, req).unwrap();
                let view = BodyV2View::parse(&body).unwrap();
                assert_eq!(view.lanes(), lane_count(n, req) as usize);

                let mut scalar = vec![0u32; n];
                view.decode_into_with(&table, &mut scalar, DecodeKernel::Scalar).unwrap();
                assert_eq!(scalar, values, "bits {bits} profile {pi} lanes {req}: scalar");
                let mut simd = vec![0u32; n];
                view.decode_into_with(&table, &mut simd, DecodeKernel::Simd).unwrap();
                assert_eq!(simd, scalar, "bits {bits} profile {pi} lanes {req}: SIMD");
                let mut thr = vec![0u32; n];
                view.decode_into_threaded_with(&table, &mut thr, 3, DecodeKernel::Simd)
                    .unwrap();
                assert_eq!(thr, scalar, "bits {bits} profile {pi} lanes {req}: threaded SIMD");
            }
        }
    }
}

/// SIMD kernel invariant continued: on corrupted v2 bodies every kernel ×
/// decode-path combination reports the *identical* outcome — the same
/// decoded buffer when a bit flip slips through the arithmetic coder, the
/// same `CorruptStream` position when it does not — for a flipped payload
/// byte in every lane, and for a truncated final-lane offset stream
/// (which is guaranteed to fail).
#[test]
fn prop_simd_kernel_matches_scalar_on_corrupt_lane_payloads() {
    use apack_repro::apack::lanes::{
        encode_body_v2, BodyV2View, DIR_ENTRY_BYTES, HEADER_BYTES,
    };
    use apack_repro::apack::DecodeKernel;
    use apack_repro::models::distributions::ValueProfile;

    // All four kernel × path outcomes for one body; Ok carries the full
    // decoded buffer, Err the CorruptStream position.
    fn outcomes(body: &[u8], table: &SymbolTable, n: usize) -> Vec<Result<Vec<u32>, usize>> {
        let view = BodyV2View::parse(body).unwrap();
        let mut all = Vec::new();
        for kernel in [DecodeKernel::Scalar, DecodeKernel::Simd] {
            for threads in [1usize, 3] {
                let mut out = vec![0u32; n];
                let r = if threads > 1 {
                    view.decode_into_threaded_with(table, &mut out, threads, kernel).map(|_| ())
                } else {
                    view.decode_into_with(table, &mut out, kernel)
                };
                all.push(match r {
                    Ok(()) => Ok(out),
                    Err(apack_repro::Error::CorruptStream { position }) => Err(position),
                    Err(e) => panic!("unexpected error {e}"),
                });
            }
        }
        all
    }

    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, 20_000, 0xC0_22);
    let n = values.len();
    let hist = Histogram::from_values(8, &values);
    let table =
        generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
    let body = encode_body_v2(&table, &values, 8).unwrap();
    let lanes = BodyV2View::parse(&body).unwrap().lanes();
    assert_eq!(lanes, 8);

    // Per-lane payload extents, recomputed from the directory bytes the
    // same way parse does (sym then ofs, cumulatively packed).
    let dir_end = HEADER_BYTES + lanes * DIR_ENTRY_BYTES;
    let mut extents = Vec::with_capacity(lanes);
    let mut off = 0usize;
    for l in 0..lanes {
        let at = HEADER_BYTES + l * DIR_ENTRY_BYTES;
        let sym_bits = u32::from_le_bytes(body[at..at + 4].try_into().unwrap()) as usize;
        let ofs_bits = u32::from_le_bytes(body[at + 4..at + 8].try_into().unwrap()) as usize;
        let len = sym_bits.div_ceil(8) + ofs_bits.div_ceil(8);
        extents.push((off, len));
        off += len;
    }

    // A flipped byte mid-payload in each lane: only that lane's stream
    // changes, and all four decode combinations must agree exactly.
    let mut rng = Rng64::new(0x51D_C0);
    for (l, &(start, len)) in extents.iter().enumerate() {
        let mut bad = body.clone();
        bad[dir_end + start + rng.below(len as u64) as usize] ^= 1 << rng.below(8);
        let all = outcomes(&bad, &table, n);
        for (i, o) in all.iter().enumerate() {
            assert_eq!(o, &all[0], "lane {l} flip: combination {i} diverged");
        }
        if let Err(position) = &all[0] {
            let lane = apack_repro::apack::lanes::lane_range(n, lanes, l);
            assert!(lane.contains(position), "lane {l} flip: position {position} escaped");
        }
    }

    // Truncated final-lane offset stream (ofs_bits zeroed, bytes dropped
    // from the tail): the first offset read in that lane must fail at the
    // same position through every combination.
    let at = HEADER_BYTES + (lanes - 1) * DIR_ENTRY_BYTES;
    let mut cut = body.clone();
    let ofs_bits = u32::from_le_bytes(cut[at + 4..at + 8].try_into().unwrap()) as usize;
    assert!(ofs_bits > 0, "ReLU lanes always carry offsets");
    cut[at + 4..at + 8].copy_from_slice(&0u32.to_le_bytes());
    cut.truncate(cut.len() - ofs_bits.div_ceil(8));
    let all = outcomes(&cut, &table, n);
    let Err(position) = &all[0] else { panic!("truncation must surface as CorruptStream") };
    let last = apack_repro::apack::lanes::lane_range(n, lanes, lanes - 1);
    assert!(last.contains(position), "truncation position {position} outside the last lane");
    for (i, o) in all.iter().enumerate() {
        assert_eq!(o, &all[0], "truncation: combination {i} diverged");
    }
}

/// Chunk-body v2 tiny-chunk invariant: every chunk size from 1 to 4096
/// values round-trips exactly, and the lane directory always records the
/// deterministic degraded lane count (`lane_count`) — small chunks fall
/// back toward a single lane rather than producing starved lanes.
#[test]
fn prop_body_v2_tiny_chunks_degrade_lanes() {
    use apack_repro::apack::lanes::{encode_body_v2, lane_count, BodyV2View};
    use apack_repro::models::distributions::ValueProfile;
    let all = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, 4096, 0x7177);
    let hist = Histogram::from_values(8, &all);
    let table =
        generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
    for n in 1..=4096usize {
        let values = &all[..n];
        let body = encode_body_v2(&table, values, 16).unwrap();
        let view = BodyV2View::parse(&body).unwrap();
        assert_eq!(view.lanes(), lane_count(n, 16) as usize, "n {n}");
        let mut out = vec![0u32; n];
        view.decode_into(&table, &mut out).unwrap();
        assert_eq!(out, values, "n {n}");
        // The threaded decoder agrees (spot-checked — spawning threads
        // for all 4096 sizes would dominate the test's runtime).
        if n % 512 == 0 || n == 1 {
            let mut out = vec![0u32; n];
            view.decode_into_threaded(&table, &mut out, 0).unwrap();
            assert_eq!(out, values, "n {n} threaded");
        }
    }
}

/// Invariant 4: sharded compression reassembles exactly for any partition
/// width.
#[test]
fn prop_coordinator_reassembly() {
    for seed in 0..12u64 {
        let mut rng = Rng64::new(0xC00D + seed);
        let n = rng.range(1, 60_000);
        let values = random_tensor(&mut rng, 8, n);
        let policy = PartitionPolicy {
            substreams: rng.range(1, 128) as u32,
            min_per_stream: rng.range(1, 4096),
        };
        let mut coord = Coordinator::new(policy);
        let sc = coord.compress(8, &values, TensorKind::Activations, None).unwrap();
        assert_eq!(coord.decompress(&sc).unwrap(), values, "seed {seed}");
    }
}

/// Store invariant: for any tensor, partition policy and range,
/// `get_range(lo..hi)` equals the corresponding slice of a full
/// `get_tensor` decode (and `get_chunk` equals its covered slice).
#[test]
fn prop_store_range_equals_tensor_slice() {
    let path = std::env::temp_dir()
        .join(format!("apack_prop_store_{}.apackstore", std::process::id()));
    for seed in 0..6u64 {
        let mut rng = Rng64::new(0x57033 + seed);
        let n = rng.range(1, 40_000);
        let values = random_tensor(&mut rng, 8, n);
        let policy = PartitionPolicy {
            substreams: rng.range(1, 32) as u32,
            min_per_stream: rng.range(1, 2048),
        };
        let mut w = StoreWriter::create(&path, policy).unwrap();
        w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
        w.finish().unwrap();

        let reader = StoreReader::open(&path).unwrap();
        let full = reader.get_tensor("t").unwrap();
        assert_eq!(full, values, "seed {seed}");
        for _ in 0..20 {
            let lo = rng.below(n as u64 + 1);
            let hi = lo + rng.below(n as u64 + 1 - lo);
            assert_eq!(
                reader.get_range("t", lo..hi).unwrap(),
                &full[lo as usize..hi as usize],
                "seed {seed} range {lo}..{hi}"
            );
        }
        let meta = reader.meta("t").unwrap();
        for ci in 0..meta.chunks.len() {
            let covered = meta.chunk_value_range(ci);
            assert_eq!(
                reader.get_chunk("t", ci).unwrap().as_slice(),
                &full[covered.start as usize..covered.end as usize],
                "seed {seed} chunk {ci}"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Invariant 5: the entropy-based size estimate tracks the real encoder
/// within ±15% on random tensors (it guides the search, so gross error
/// would corrupt table quality).
#[test]
fn prop_estimator_accuracy() {
    let mut checked = 0;
    for seed in 0..15u64 {
        let mut rng = Rng64::new(0xE57 + seed);
        let values = random_tensor(&mut rng, 8, 30_000);
        let hist = Histogram::from_values(8, &values);
        let t = generate_table(&hist, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let est = estimate_bits(&hist, &t);
        let (_, sb, _, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        let actual = (sb + ob + METADATA_BITS) as f64;
        let ratio = actual / est;
        assert!((0.85..1.15).contains(&ratio), "seed {seed}: ratio {ratio}");
        checked += 1;
    }
    assert_eq!(checked, 15);
}

/// Invariant 6: baseline codecs roundtrip on random tensors.
#[test]
fn prop_baselines_roundtrip() {
    for seed in 0..30u64 {
        let mut rng = Rng64::new(0xBA5E + seed);
        let n = rng.range(0, 4000);
        let values = random_tensor(&mut rng, 8, n);
        assert_eq!(rle_decode(&rle_encode(&values)), values, "rle seed {seed}");
        assert_eq!(rlez_decode(&rlez_encode(&values)), values, "rlez seed {seed}");
        for cfg in [
            ShapeShifterConfig::paper_8b(),
            ShapeShifterConfig::no_zero_vector(8),
            ShapeShifterConfig::magnitude_only(8),
        ] {
            assert_eq!(
                ss_decode(&ss_encode(&values, &cfg), &cfg),
                values,
                "ss seed {seed} cfg {cfg:?}"
            );
        }
    }
}

/// Entropy lower-bounds every scheme: APack's footprint is never below
/// the tensor's exact entropy (lossless coding bound).
#[test]
fn prop_apack_respects_entropy_bound() {
    for seed in 0..10u64 {
        let mut rng = Rng64::new(0xB0C + seed);
        let values = random_tensor(&mut rng, 8, 40_000);
        let hist = Histogram::from_values(8, &values);
        let t =
            generate_table(&hist, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let (_, sb, _, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        let bits_per_value = (sb + ob) as f64 / values.len() as f64;
        assert!(
            bits_per_value + 1e-6 >= hist.entropy(),
            "seed {seed}: {bits_per_value} < H {}",
            hist.entropy()
        );
    }
}

/// Bit-stream substrate: arbitrary field sequences roundtrip exactly.
#[test]
fn prop_bitstream_roundtrip() {
    for seed in 0..50u64 {
        let mut rng = Rng64::new(0xB175 + seed);
        let n = rng.range(0, 500);
        let fields: Vec<(u64, u32)> = (0..n)
            .map(|_| {
                let c = rng.range(1, 57) as u32;
                (rng.below(1u64 << c), c)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, c) in &fields {
            w.push_bits(v, c);
        }
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        for &(v, c) in &fields {
            assert_eq!(r.read_bits(c), v, "seed {seed}");
        }
    }
}
