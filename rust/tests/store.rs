//! APackStore integration tests: the full zoo packed into one store and
//! read back bit-exactly, random access touching only the chunks it
//! covers (byte-accounted), concurrent readers over one handle, and the
//! sharded layout round-tripping bit-identically to the single-file one.

use std::path::PathBuf;
use std::sync::Arc;

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::eval::{EVAL_SEED, PROFILE_SAMPLES};
use apack_repro::models::trace::ModelTrace;
use apack_repro::models::zoo::all_models;
use apack_repro::store::{
    compact_store, encode_tensor_with, pack_model_zoo, pack_model_zoo_sharded,
    store_versions, verify_store, Backend, BodyConfig, ShardedStoreWriter, StoreAppender,
    StoreHandle, StoreReader, StoreWriter,
};
use apack_repro::util::Rng64;

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("apack_itest_{}_{tag}.apackstore", std::process::id()))
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("apack_itest_{}_{tag}.apackstore.d", std::process::id()))
}

/// Acceptance: all 24 Table-II models into one store, every tensor back
/// bit-exactly (weights and studied activations).
#[test]
fn zoo_pack_roundtrips_every_tensor() {
    let path = temp_path("zoo");
    let models = all_models();
    let sample_cap = 512;
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 64 };
    let summary = pack_model_zoo(&path, &models, sample_cap, policy).unwrap();
    assert!(summary.tensors > models.len(), "at least one tensor per model");

    let reader = StoreReader::open(&path).unwrap();
    let mut tensors_checked = 0usize;
    for cfg in &models {
        // Re-synthesize with the writer's seeds: bit-exact reference.
        let trace = ModelTrace::synthesize(cfg, sample_cap, PROFILE_SAMPLES, EVAL_SEED);
        for l in &trace.layers {
            let wname = format!("{}/layer{:03}/weights", cfg.name, l.layer_idx);
            assert_eq!(reader.get_tensor(&wname).unwrap(), l.weights, "{wname}");
            tensors_checked += 1;
            if !l.activations.is_empty() {
                let aname = format!("{}/layer{:03}/activations", cfg.name, l.layer_idx);
                assert_eq!(reader.get_tensor(&aname).unwrap(), l.activations, "{aname}");
                tensors_checked += 1;
            }
        }
    }
    assert_eq!(tensors_checked, reader.tensor_count(), "every stored tensor checked");
    assert_eq!(tensors_checked, summary.tensors);
    std::fs::remove_file(&path).ok();
}

/// Acceptance: `get_chunk` / `get_range` read and decode only the chunks
/// they cover — asserted by exact byte accounting against the index.
#[test]
fn random_access_reads_only_covering_chunks() {
    let path = temp_path("accounting");
    let n = 64_000usize;
    let values: Vec<u32> = {
        let mut rng = Rng64::new(42);
        (0..n).map(|_| if rng.chance(0.5) { 0 } else { rng.below(256) as u32 }).collect()
    };
    let policy = PartitionPolicy { substreams: 16, min_per_stream: 256 };
    let mut w = StoreWriter::create(&path, policy).unwrap();
    w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
    w.finish().unwrap();

    // Cache disabled so every read is visible in the byte counters.
    let reader = StoreReader::with_cache_capacity(&path, 0).unwrap();
    let meta = reader.meta("t").unwrap();
    assert_eq!(meta.chunks.len(), 16);
    let per = meta.values_per_chunk;
    assert_eq!(per, 4000);
    let chunk_bytes: Vec<u64> = meta.chunks.iter().map(|c| c.len).collect();
    let total_bytes: u64 = chunk_bytes.iter().sum();

    // Single chunk: exactly that chunk's bytes, one decode.
    reader.reset_stats();
    let chunk5 = reader.get_chunk("t", 5).unwrap();
    assert_eq!(chunk5.as_slice(), &values[5 * per as usize..6 * per as usize]);
    assert_eq!(reader.stats().bytes_read, chunk_bytes[5]);
    assert_eq!(reader.stats().chunks_decoded, 1);

    // Range within one chunk: that chunk only, not the whole tensor.
    reader.reset_stats();
    let got = reader.get_range("t", per + 7..2 * per - 9).unwrap();
    assert_eq!(got, &values[(per + 7) as usize..(2 * per - 9) as usize]);
    assert_eq!(reader.stats().bytes_read, chunk_bytes[1]);

    // Range spanning three chunks: exactly those three.
    reader.reset_stats();
    let lo = 2 * per + 100;
    let hi = 5 * per - 100;
    let got = reader.get_range("t", lo..hi).unwrap();
    assert_eq!(got, &values[lo as usize..hi as usize]);
    assert_eq!(
        reader.stats().bytes_read,
        chunk_bytes[2] + chunk_bytes[3] + chunk_bytes[4]
    );
    assert_eq!(reader.stats().chunks_decoded, 3);

    // Full tensor: all bytes, once each.
    reader.reset_stats();
    assert_eq!(reader.get_tensor("t").unwrap(), values);
    assert_eq!(reader.stats().bytes_read, total_bytes);
    assert_eq!(reader.stats().chunks_decoded, 16);
    std::fs::remove_file(&path).ok();
}

/// Many threads over one shared reader: every read verifies, and the
/// cache turns repeat traffic into hits.
#[test]
fn concurrent_readers_share_one_store() {
    let path = temp_path("concurrent");
    let n = 40_000usize;
    let values: Vec<u32> = {
        let mut rng = Rng64::new(9);
        (0..n).map(|_| rng.below(200) as u32).collect()
    };
    let mut w =
        StoreWriter::create(&path, PartitionPolicy { substreams: 8, min_per_stream: 256 })
            .unwrap();
    w.add_tensor("t", 8, &values, TensorKind::Weights).unwrap();
    w.finish().unwrap();

    let reader = Arc::new(StoreReader::open(&path).unwrap());
    std::thread::scope(|scope| {
        for tid in 0..6u64 {
            let reader = Arc::clone(&reader);
            let values = &values;
            scope.spawn(move || {
                let mut rng = Rng64::new(100 + tid);
                for _ in 0..50 {
                    let lo = rng.below(n as u64);
                    let hi = (lo + 1 + rng.below(2000)).min(n as u64);
                    assert_eq!(
                        reader.get_range("t", lo..hi).unwrap(),
                        &values[lo as usize..hi as usize]
                    );
                }
            });
        }
    });
    let stats = reader.stats();
    assert!(stats.cache_hits > 0, "repeat traffic must hit the cache");
    // Everything fits in the cache, so decodes are bounded by chunk count
    // × thread count (concurrent first-misses may race before the insert
    // lands), far below the 300 total reads.
    assert!(stats.chunks_decoded <= 8 * 6, "chunks decoded {}", stats.chunks_decoded);
    std::fs::remove_file(&path).ok();
}

/// Property: for every shard count N=1..4, a sharded store holds exactly
/// the same tensors, bit-identically, as the single-file store built from
/// the same data — full decodes, random ranges (including ranges that
/// straddle chunk boundaries), and chunk reads all agree with the
/// in-memory slice, on both IO backends.
#[test]
fn sharded_store_matches_single_file_bit_exact() {
    // Varied tensor population: sizes around chunk boundaries, a tiny
    // tensor, an empty one, and a multi-chunk one.
    let mut rng = Rng64::new(0x51AB);
    let tensors: Vec<(String, Vec<u32>)> = [0usize, 1, 63, 1024, 1025, 5000, 12_001]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let v: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
            (format!("m/layer{i:03}/weights"), v)
        })
        .collect();
    let policy = PartitionPolicy { substreams: 4, min_per_stream: 256 };

    let single_path = temp_path("shardeq");
    let mut w = StoreWriter::create(&single_path, policy).unwrap();
    for (name, v) in &tensors {
        w.add_tensor(name, 8, v, TensorKind::Weights).unwrap();
    }
    w.finish().unwrap();
    let single = StoreHandle::open(&single_path).unwrap();

    for shards in 1..=4usize {
        let dir = temp_dir(&format!("shardeq{shards}"));
        let mut w = ShardedStoreWriter::create(&dir, shards, policy).unwrap();
        for (name, v) in &tensors {
            w.add_tensor(name, 8, v, TensorKind::Weights).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.shards, shards);
        assert_eq!(summary.tensors, tensors.len());

        for backend in [Backend::Mmap, Backend::File] {
            let sharded = StoreHandle::open_with(&dir, backend, 1 << 20).unwrap();
            assert_eq!(sharded.shard_count(), shards);
            assert_eq!(sharded.tensor_count(), single.tensor_count());
            let mut names: Vec<String> = sharded.tensor_names();
            names.sort_unstable();
            let mut expect_names: Vec<String> = single.tensor_names();
            expect_names.sort_unstable();
            assert_eq!(names, expect_names, "N={shards}");

            for (name, v) in &tensors {
                // Full decode: bit-identical to the single-file store.
                assert_eq!(&sharded.get_tensor(name).unwrap(), v, "N={shards} {name}");
                assert_eq!(
                    sharded.get_tensor(name).unwrap(),
                    single.get_tensor(name).unwrap()
                );
                let meta = sharded.meta(name).unwrap();
                assert_eq!(meta.n_values, v.len() as u64);

                // Random ranges == slices, biased toward chunk boundaries.
                let n = v.len() as u64;
                for trial in 0..20u64 {
                    let (lo, hi) = if n == 0 {
                        (0, 0)
                    } else if trial % 4 == 0 && meta.chunks.len() > 1 {
                        // Straddle a chunk boundary explicitly.
                        let b = meta.values_per_chunk
                            * (1 + trial % (meta.chunks.len() as u64 - 1).max(1));
                        let b = b.min(n);
                        (b.saturating_sub(1 + trial % 7), (b + 1 + trial % 5).min(n))
                    } else {
                        let lo = rng.below(n);
                        (lo, (lo + 1 + rng.below(n - lo)).min(n))
                    };
                    assert_eq!(
                        sharded.get_range(name, lo..hi).unwrap(),
                        &v[lo as usize..hi as usize],
                        "N={shards} {name} {lo}..{hi}"
                    );
                }
                // Chunk reads agree too.
                for ci in 0..meta.chunks.len() {
                    let covered = meta.chunk_value_range(ci);
                    assert_eq!(
                        sharded.get_chunk(name, ci).unwrap().as_slice(),
                        &v[covered.start as usize..covered.end as usize]
                    );
                }
            }
            let report = sharded.verify().unwrap();
            assert_eq!(report.shards, shards);
            assert_eq!(report.tensors, tensors.len());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_file(&single_path).ok();
}

/// Acceptance: the full 24-model zoo sharded over 4 files round-trips
/// bit-exactly against the single-file pack of the same traces, and the
/// per-shard parallel verify covers every chunk.
#[test]
fn zoo_sharded_pack_matches_single_file() {
    let single_path = temp_path("zooshard1");
    let dir = temp_dir("zooshard4");
    let models = all_models();
    let sample_cap = 256;
    let policy = PartitionPolicy { substreams: 4, min_per_stream: 64 };

    let single_summary = pack_model_zoo(&single_path, &models, sample_cap, policy).unwrap();
    let sharded_summary =
        pack_model_zoo_sharded(&dir, &models, sample_cap, policy, 4).unwrap();
    assert_eq!(sharded_summary.tensors, single_summary.tensors);
    assert_eq!(sharded_summary.shards, 4, "zoo is large enough for 4 shards");

    let single = StoreHandle::open(&single_path).unwrap();
    let sharded = StoreHandle::open(&dir).unwrap();
    assert_eq!(sharded.tensor_count(), single.tensor_count());
    for name in single.tensor_names() {
        assert_eq!(
            sharded.get_tensor(&name).unwrap(),
            single.get_tensor(&name).unwrap(),
            "{name}"
        );
    }
    let report = sharded.verify().unwrap();
    assert_eq!(report.shards, 4);
    assert_eq!(report.tensors, single.tensor_count());
    assert_eq!(report.chunks, single.verify().unwrap().chunks);

    std::fs::remove_file(&single_path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-version matrix (ISSUE 7): the same zoo subset packed with v1 and
/// v2 chunk bodies decodes bit-identically tensor for tensor; the
/// footprint delta (v2's lane-directory framing) is reported; and on the
/// 8-bit tensors the aggregate lane-directory overhead stays under 1% of
/// the v2 body bytes. A one-chunk-per-tensor policy and a large sample
/// cap keep chunks big enough for the full 16-lane fan-out — the regime
/// the <1% bound is specified for (tiny chunks degrade to fewer lanes,
/// paying proportionally less directory).
#[test]
fn cross_version_zoo_matrix_bit_exact_and_overhead_bounded() {
    use apack_repro::apack::lanes::{lane_count, DEFAULT_LANES};
    use apack_repro::models::zoo::model_by_name;
    use apack_repro::store::{pack_model_zoo_with, BodyConfig, PackOptions};

    let models: Vec<_> = ["ncf", "alexnet_eyeriss"]
        .iter()
        .map(|n| model_by_name(n).unwrap())
        .collect();
    let sample_cap = 131_072;
    let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };

    let v1_path = temp_path("matrix_v1");
    let v2_path = temp_path("matrix_v2");
    let v1_opts = PackOptions { body: BodyConfig::v1(), ..PackOptions::default() };
    let v1 = pack_model_zoo_with(&v1_path, &models, sample_cap, policy, &v1_opts).unwrap();
    let v2 =
        pack_model_zoo_with(&v2_path, &models, sample_cap, policy, &PackOptions::default())
            .unwrap();
    assert_eq!(v1.tensors, v2.tensors);
    assert_eq!(v1.chunks, v2.chunks);

    let r1 = StoreHandle::open(&v1_path).unwrap();
    let r2 = StoreHandle::open(&v2_path).unwrap();
    for name in r1.tensor_names() {
        assert_eq!(
            r1.get_tensor(&name).unwrap(),
            r2.get_tensor(&name).unwrap(),
            "{name}: v1 and v2 stores must decode identically"
        );
    }
    println!(
        "cross-version footprint: v1 {} B, v2 {} B ({:+} B for lane directories)",
        v1.file_bytes,
        v2.file_bytes,
        v2.file_bytes as i64 - v1.file_bytes as i64
    );

    // Lane-directory overhead, computed from the index: each v2 chunk
    // body spends a 12-byte header plus 12 bytes per lane on framing.
    let mut dir_bytes = 0u64;
    let mut body_bytes = 0u64;
    for t in r2.tensor_metas().iter().filter(|t| t.bits == 8 && !t.chunks.is_empty()) {
        assert_eq!((t.body_version, t.lanes), (2, DEFAULT_LANES), "{}", t.name);
        for c in &t.chunks {
            dir_bytes += 12 + 12 * lane_count(c.n_values as usize, DEFAULT_LANES) as u64;
            body_bytes += c.len;
        }
    }
    assert!(body_bytes > 0, "the subset must contain 8-bit tensors");
    let overhead = dir_bytes as f64 / body_bytes as f64;
    println!(
        "lane-directory overhead on 8-bit tensors: {dir_bytes} B over {body_bytes} B \
         ({:.3}%)",
        100.0 * overhead
    );
    assert!(
        overhead < 0.01,
        "lane-directory overhead {:.3}% exceeds the 1% budget",
        100.0 * overhead
    );

    std::fs::remove_file(&v1_path).ok();
    std::fs::remove_file(&v2_path).ok();
}

/// Store-level verify passes on a clean store and the footprint numbers
/// in the index are consistent with the file.
#[test]
fn verify_and_footprint_consistency() {
    let path = temp_path("verifyfp");
    let values: Vec<u32> = (0..20_000u32).map(|i| (i * 2654435761) >> 26).collect();
    let mut w =
        StoreWriter::create(&path, PartitionPolicy { substreams: 4, min_per_stream: 64 })
            .unwrap();
    w.add_tensor("t", 8, &values, TensorKind::Weights).unwrap();
    let summary = w.finish().unwrap();

    let reader = StoreReader::open(&path).unwrap();
    let report = reader.verify().unwrap();
    assert_eq!(report.tensors, 1);
    assert_eq!(report.chunks, 4);
    let meta = reader.meta("t").unwrap();
    assert_eq!(report.bytes, meta.compressed_bytes());
    // The file holds the chunk payload plus footer/trailer framing only.
    let disk = std::fs::metadata(&path).unwrap().len();
    assert_eq!(disk, summary.file_bytes);
    assert!(disk > meta.compressed_bytes());
    assert!(disk < meta.compressed_bytes() + 4096, "framing overhead is bounded");
    std::fs::remove_file(&path).ok();
}

/// Live mutation end-to-end through the public API: replace one tensor,
/// add one, tombstone one — committed as a single new generation — read
/// everything back bit-exactly, then compact and check the history
/// collapses to one parentless generation with identical live content.
#[test]
fn live_append_and_compact_roundtrip() {
    let path = temp_path("live");
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
    let mut rng = Rng64::new(0x11FE);
    let mut mk = |n: usize| -> Vec<u32> {
        (0..n).map(|_| if rng.chance(0.5) { 0 } else { rng.below(256) as u32 }).collect()
    };
    let a0 = mk(12_000);
    let b0 = mk(9_000);
    let mut w = StoreWriter::create(&path, policy).unwrap();
    w.add_tensor("a", 8, &a0, TensorKind::Weights).unwrap();
    w.add_tensor("b", 8, &b0, TensorKind::Weights).unwrap();
    w.finish().unwrap();

    // Generation 1: replace "a", add "c", drop "b".
    let a1 = mk(12_000);
    let c1 = mk(6_000);
    let encode = |name: &str, values: &[u32]| {
        encode_tensor_with(
            &policy,
            BodyConfig::default(),
            name,
            8,
            values,
            TensorKind::Weights,
            None,
            0,
        )
        .unwrap()
    };
    let mut appender = StoreAppender::open(&path).unwrap();
    assert_eq!(appender.generation(), 0);
    appender.append_encoded(encode("a", &a1)).unwrap();
    appender.append_encoded(encode("c", &c1)).unwrap();
    assert!(appender.tombstone("b"), "b is live and must tombstone");
    assert!(!appender.tombstone("b"), "double tombstone is a no-op");
    let summary = appender.commit().unwrap();
    assert_eq!(summary.generation, 1);
    assert_eq!(summary.tensors, 2);
    assert_eq!((summary.tensors_added, summary.tensors_replaced, summary.tombstoned), (1, 1, 1));

    let check_live = |reader: &StoreReader| {
        assert_eq!(reader.get_tensor("a").unwrap(), a1, "replacement version wins");
        assert_eq!(reader.get_tensor("c").unwrap(), c1, "appended tensor readable");
        assert!(reader.meta("b").is_err(), "tombstoned tensor gone from the index");
    };
    for backend in [Backend::Mmap, Backend::File] {
        let reader = StoreReader::open_with(&path, backend, 0).unwrap();
        assert_eq!(reader.generation(), 1, "{backend:?}");
        check_live(&reader);
    }
    let chain = store_versions(&path).unwrap();
    assert_eq!(chain.len(), 2, "both generations on disk before compaction");
    assert!(verify_store(&path, Backend::Mmap).is_clean());

    // Compaction drops the superseded "a" and the tombstoned "b" bytes
    // and restarts the chain at a parentless generation.
    let before = std::fs::metadata(&path).unwrap().len();
    let compacted = compact_store(&path, None).unwrap();
    assert_eq!(compacted.generation, 2);
    assert_eq!(compacted.tensors, 2);
    assert!(compacted.reclaimed() > 0, "dead versions must free bytes");
    let after = std::fs::metadata(&path).unwrap().len();
    assert!(after < before, "compaction must shrink the file: {after} vs {before}");
    for backend in [Backend::Mmap, Backend::File] {
        let reader = StoreReader::open_with(&path, backend, 0).unwrap();
        assert_eq!(reader.generation(), 2, "{backend:?}");
        check_live(&reader);
    }
    let chain = store_versions(&path).unwrap();
    assert_eq!(chain.len(), 1, "compaction collapses the history");
    assert_eq!(chain[0].generation, 2);
    assert!(verify_store(&path, Backend::Mmap).is_clean());

    // A handle compacts live and lands on the same content.
    let handle = StoreHandle::open(&path).unwrap();
    assert_eq!(handle.generation(), 2);
    assert_eq!(handle.get_tensor("a").unwrap().as_slice(), &a1[..]);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(format!("{}.gen", path.display())).ok();
}
