//! Serving-layer integration tests: concurrency stress with bit-exact
//! verification, admission control under saturation (typed shedding, no
//! hangs), coalescing under duplicate storms, deadline expiry, and the
//! prefetcher warming the chunk cache.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::distributions::ValueProfile;
use apack_repro::serving::{
    PrefetchConfig, Request, ServingConfig, ServingEngine, SingleFlight, Ticket,
};
use apack_repro::store::{
    Backend, FaultConfig, FaultPlan, ShardedStoreWriter, StoreHandle, StoreWriter,
};
use apack_repro::util::Rng64;
use apack_repro::Error;

fn tensor_values(n: usize, seed: u64) -> Vec<u32> {
    ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, seed)
}

/// Build a store (single-file or sharded) of `n_tensors` × `n_values`.
fn build_store(
    tag: &str,
    n_tensors: usize,
    n_values: usize,
    shards: usize,
) -> (PathBuf, HashMap<String, Vec<u32>>) {
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 256 };
    let tensors: Vec<(String, Vec<u32>)> = (0..n_tensors)
        .map(|i| (format!("t{i}"), tensor_values(n_values, 7000 + i as u64)))
        .collect();
    let path = if shards > 1 {
        let dir = std::env::temp_dir().join(format!(
            "apack_serving_{}_{tag}.apackstore.d",
            std::process::id()
        ));
        let mut writer = ShardedStoreWriter::create(&dir, shards, policy).unwrap();
        for (name, values) in &tensors {
            writer.add_tensor(name, 8, values, TensorKind::Activations).unwrap();
        }
        writer.finish().unwrap();
        dir
    } else {
        let file = std::env::temp_dir().join(format!(
            "apack_serving_{}_{tag}.apackstore",
            std::process::id()
        ));
        let mut writer = StoreWriter::create(&file, policy).unwrap();
        for (name, values) in &tensors {
            writer.add_tensor(name, 8, values, TensorKind::Activations).unwrap();
        }
        writer.finish().unwrap();
        file
    };
    (path, tensors.into_iter().collect())
}

fn cleanup(path: &PathBuf) {
    if path.is_dir() {
        std::fs::remove_dir_all(path).ok();
    } else {
        std::fs::remove_file(path).ok();
    }
}

/// Many client threads through one engine, every response verified
/// bit-exact against the reference decode. Covers both store layouts.
#[test]
fn stress_concurrent_clients_bit_exact() {
    for shards in [1usize, 3] {
        let (path, reference) = build_store("stress", 3, 30_000, shards);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig {
                workers: 4,
                queue_depth: 128,
                coalescing: true,
                deadline: None,
                prefetch: Some(PrefetchConfig {
                    interval: Duration::from_millis(1),
                    ..PrefetchConfig::default()
                }),
                slo: None,
            },
        )
        .unwrap();
        let names: Vec<String> = reference.keys().cloned().collect();

        let clients = 8usize;
        let requests = 120usize;
        std::thread::scope(|scope| {
            for tid in 0..clients {
                let engine = &engine;
                let reference = &reference;
                let names = &names;
                scope.spawn(move || {
                    let mut rng = Rng64::new(0xAB + tid as u64);
                    for i in 0..requests {
                        let name = &names[rng.below(names.len() as u64) as usize];
                        let expect = &reference[name];
                        let meta = engine.store().meta(name).unwrap();
                        match i % 3 {
                            0 => {
                                // Hot chunk: duplicate-heavy on purpose.
                                let covered = meta.chunk_value_range(0);
                                let got = engine.get_chunk(name, 0).unwrap();
                                assert_eq!(
                                    got.as_slice(),
                                    &expect[covered.start as usize..covered.end as usize]
                                );
                            }
                            1 => {
                                let n = meta.n_values;
                                let lo = rng.below(n);
                                let span = 1 + rng.below((n - lo).min(5000));
                                let got = engine.get_range(name, lo..lo + span).unwrap();
                                assert_eq!(
                                    got.as_slice(),
                                    &expect[lo as usize..(lo + span) as usize]
                                );
                            }
                            _ => {
                                let ci = rng.below(meta.chunks.len() as u64) as usize;
                                let covered = meta.chunk_value_range(ci);
                                let got = engine.get_chunk(name, ci).unwrap();
                                assert_eq!(
                                    got.as_slice(),
                                    &expect[covered.start as usize..covered.end as usize]
                                );
                            }
                        }
                    }
                });
            }
        });

        let m = engine.metrics();
        let total = (clients * requests) as u64;
        assert_eq!(m.submitted, total, "{shards} shard(s)");
        assert_eq!(m.completed, total, "closed-loop clients never overflow the queue");
        assert_eq!(m.shed_total(), 0);
        assert_eq!(m.latency.count, total);
        assert!(m.queue_depth_max <= 128);
        let stats = engine.stats();
        assert_eq!(stats.shed_requests, 0);
        assert!(
            stats.cache_hits + stats.chunks_decoded > 0,
            "traffic must have flowed through the store"
        );
        drop(engine);
        cleanup(&path);
    }
}

/// A saturated queue sheds with `Error::Overloaded` instead of hanging,
/// and every admitted request still answers bit-exactly.
#[test]
fn admission_control_sheds_instead_of_hanging() {
    let (path, reference) = build_store("admission", 1, 60_000, 1);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 1,
            queue_depth: 2,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        },
    )
    .unwrap();

    // Flood: full-tensor decodes are slow, submits are instant, so the
    // 2-deep queue must overflow.
    let flood = 64usize;
    let mut admitted: Vec<Ticket> = Vec::new();
    let mut shed = 0u64;
    for _ in 0..flood {
        match engine.submit(Request::Tensor { tensor: "t0".to_string() }) {
            Ok(ticket) => admitted.push(ticket),
            Err(Error::Overloaded { queue_depth, deadline_expired }) => {
                assert_eq!(queue_depth, 2);
                assert!(!deadline_expired);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "64 instant submits must overflow a 2-deep queue");

    let expect = &reference["t0"];
    let admitted_count = admitted.len() as u64;
    for ticket in admitted {
        assert_eq!(ticket.wait().unwrap().as_slice(), &expect[..]);
    }
    let m = engine.metrics();
    assert_eq!(m.submitted, admitted_count);
    assert_eq!(m.completed, admitted_count);
    assert_eq!(m.shed_queue_full, shed);
    assert_eq!(admitted_count + shed, flood as u64);
    assert_eq!(engine.stats().shed_requests, shed);
    drop(engine);
    cleanup(&path);
}

/// A zero deadline expires every queued request: typed deadline shed.
#[test]
fn expired_deadlines_shed_at_pop() {
    let (path, _) = build_store("deadline", 1, 5_000, 1);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 1,
            queue_depth: 64,
            coalescing: true,
            deadline: Some(Duration::ZERO),
            prefetch: None,
            slo: None,
        },
    )
    .unwrap();
    for _ in 0..6 {
        match engine.get_chunk("t0", 0) {
            Err(Error::Overloaded { deadline_expired, .. }) => assert!(deadline_expired),
            other => panic!("zero deadline must shed, got {other:?}"),
        }
    }
    // A per-request override lifts the engine default.
    let got = engine
        .submit_with_deadline(
            Request::Chunk { tensor: "t0".to_string(), chunk: 0 },
            Some(Duration::from_secs(60)),
        )
        .unwrap()
        .wait();
    assert!(got.is_ok(), "a generous per-request deadline must serve normally");
    let m = engine.metrics();
    assert_eq!(m.shed_deadline, 6);
    assert_eq!(m.completed, 1);
    drop(engine);
    cleanup(&path);
}

/// Duplicate burst against an uncached store: coalescing ON decodes
/// measurably fewer chunks than OFF at identical (bit-exact) results.
#[test]
fn coalescing_cuts_duplicate_decodes() {
    let (path, reference) = build_store("coalesce", 1, 40_000, 1);
    let expect = &reference["t0"];
    let burst = 96usize;
    let mut decoded = [0u64; 2];
    for (mode, coalescing) in [false, true].into_iter().enumerate() {
        // cache_values = 0: every decode is real, so the counter isolates
        // the single-flight effect.
        let store =
            Arc::new(StoreHandle::open_with(&path, Backend::Mmap, 0).unwrap());
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig {
                workers: 4,
                queue_depth: burst + 8,
                coalescing,
                deadline: None,
                prefetch: None,
                slo: None,
            },
        )
        .unwrap();
        let covered = store.meta("t0").unwrap().chunk_value_range(1);
        let tickets: Vec<Ticket> = (0..burst)
            .map(|_| {
                engine
                    .submit(Request::Chunk { tensor: "t0".to_string(), chunk: 1 })
                    .unwrap()
            })
            .collect();
        for ticket in tickets {
            assert_eq!(
                ticket.wait().unwrap().as_slice(),
                &expect[covered.start as usize..covered.end as usize],
                "coalescing must never change bytes"
            );
        }
        let stats = engine.stats();
        decoded[mode] = stats.chunks_decoded;
        if coalescing {
            assert_eq!(stats.coalesced_reads, engine.metrics().coalesced_decodes);
            assert!(stats.coalesced_reads > 0, "duplicates must share flights");
        } else {
            assert_eq!(stats.coalesced_reads, 0);
        }
        drop(engine);
    }
    assert_eq!(decoded[0], burst as u64, "coalescing off: every duplicate decodes");
    assert!(
        decoded[1] < decoded[0],
        "coalescing on must decode less: {} vs {}",
        decoded[1],
        decoded[0]
    );
    cleanup(&path);
}

/// The prefetcher decodes hot chunks back into a cleared cache.
#[test]
fn prefetcher_warms_cleared_cache() {
    let (path, reference) = build_store("prefetch", 1, 20_000, 1);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 2,
            queue_depth: 64,
            coalescing: true,
            deadline: None,
            prefetch: Some(PrefetchConfig {
                interval: Duration::from_millis(1),
                top_k: 8,
                min_touches: 1,
            }),
            slo: None,
        },
    )
    .unwrap();
    let expect = &reference["t0"];
    let covered = store.meta("t0").unwrap().chunk_value_range(2);

    // Keep chunk 2 hot while repeatedly clearing the cache: the prefetch
    // thread must eventually decode it back in on its own.
    let mut warmed = false;
    for _ in 0..400 {
        for _ in 0..4 {
            let got = engine.get_chunk("t0", 2).unwrap();
            assert_eq!(
                got.as_slice(),
                &expect[covered.start as usize..covered.end as usize]
            );
        }
        store.clear_cache();
        std::thread::sleep(Duration::from_millis(2));
        if store.stats().prefetched_chunks > 0 {
            warmed = true;
            break;
        }
    }
    assert!(warmed, "prefetcher never warmed the cache in 400 rounds");
    drop(engine);
    cleanup(&path);
}

/// Regression (ISSUE 10): a leader's *transient* failure must not be
/// shared with coalesced followers the way permanent corruption is —
/// followers re-enter the flight table and retry independently, so one
/// IO flake never fans out across a duplicate storm.
#[test]
fn transient_singleflight_failures_are_not_shared_with_followers() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Barrier;
    let flight = SingleFlight::new();
    let attempts = AtomicU64::new(0);
    let transient_failures = AtomicU64::new(0);
    let oks = AtomicU64::new(0);
    let barrier = Barrier::new(6);
    std::thread::scope(|scope| {
        for _ in 0..6 {
            scope.spawn(|| {
                barrier.wait();
                let (res, _) = flight.run("t", 0, || {
                    if attempts.fetch_add(1, Ordering::Relaxed) == 0 {
                        // First leader: hold the flight until every peer
                        // has coalesced onto it, then fail transiently.
                        std::thread::sleep(Duration::from_millis(100));
                        Err(Error::Transient("injected flake".into()))
                    } else {
                        Ok(Arc::new(vec![42u32]))
                    }
                });
                match res {
                    Err(e) => {
                        assert!(e.is_transient(), "only the injected flake may surface");
                        transient_failures.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(v) => {
                        assert_eq!(v[0], 42);
                        oks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(
        transient_failures.load(Ordering::Relaxed),
        1,
        "the failing leader keeps its own error (its caller retries); nobody adopts it"
    );
    assert_eq!(oks.load(Ordering::Relaxed), 5, "every follower retried independently");
    assert!(attempts.load(Ordering::Relaxed) >= 2, "a fresh decode must have run");
}

/// Injected transient IO faults first exhaust the store's own per-read
/// retry budget; the serving engine's bounded retry loop then re-issues
/// the decode and the request still answers bit-exactly. Transient
/// failures surface as typed retries in the metrics, never as a final
/// answer shared with coalesced followers.
#[test]
fn engine_retries_through_transient_store_faults() {
    let (path, reference) = build_store("transient", 1, 20_000, 1);
    let expect = &reference["t0"];
    // Every payload read fails until the 6-fault budget runs dry: the
    // store-level retry loop (1 try + 4 retries) exhausts on the first
    // decode attempt and surfaces Error::Transient; the engine's own
    // retry then drains the budget and succeeds.
    let plan = FaultPlan::new(FaultConfig {
        read_error_rate: 1.0,
        max_injected_errors: 6,
        ..FaultConfig::default()
    });
    let store = Arc::new(
        StoreHandle::open_with_plan(&path, Backend::File, 0, Some(&plan)).unwrap(),
    );
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 2,
            queue_depth: 32,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        },
    )
    .unwrap();
    let covered = store.meta("t0").unwrap().chunk_value_range(0);
    let got = engine.get_chunk("t0", 0).unwrap();
    assert_eq!(got.as_slice(), &expect[covered.start as usize..covered.end as usize]);
    let m = engine.metrics();
    assert!(m.retries >= 1, "the engine must have re-issued the decode");
    let stats = engine.stats();
    assert!(stats.transient_retries >= 1, "store-level retries must surface in stats");
    assert!(plan.injected_errors() >= 6, "the whole fault budget was consumed");
    drop(engine);
    cleanup(&path);
}

/// Online compaction mid-traffic: clients hammer the engine while the
/// store compacts to a new generation underneath them. Every response
/// stays bit-exact (requests pin a generation snapshot; the swap is a
/// pointer flip), nothing is shed, and the handle lands on the advanced
/// generation. Covers both store layouts.
#[test]
fn online_compaction_under_traffic_stays_bit_exact() {
    for shards in [1usize, 3] {
        let (path, reference) = build_store("livecompact", 2, 24_000, shards);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig {
                workers: 4,
                queue_depth: 256,
                coalescing: true,
                deadline: None,
                prefetch: None,
                slo: None,
            },
        )
        .unwrap();
        let names: Vec<String> = reference.keys().cloned().collect();
        let clients = 4usize;
        let requests = 150usize;
        std::thread::scope(|scope| {
            for tid in 0..clients {
                let engine = &engine;
                let reference = &reference;
                let names = &names;
                scope.spawn(move || {
                    let mut rng = Rng64::new(0xC0 + tid as u64);
                    for i in 0..requests {
                        let name = &names[rng.below(names.len() as u64) as usize];
                        let expect = &reference[name];
                        let meta = engine.store().meta(name).unwrap();
                        if i % 2 == 0 {
                            let ci = rng.below(meta.chunks.len() as u64) as usize;
                            let covered = meta.chunk_value_range(ci);
                            let got = engine.get_chunk(name, ci).unwrap();
                            assert_eq!(
                                got.as_slice(),
                                &expect[covered.start as usize..covered.end as usize]
                            );
                        } else {
                            let n = meta.n_values;
                            let lo = rng.below(n);
                            let span = 1 + rng.below((n - lo).min(4000));
                            let got = engine.get_range(name, lo..lo + span).unwrap();
                            assert_eq!(
                                got.as_slice(),
                                &expect[lo as usize..(lo + span) as usize]
                            );
                        }
                    }
                });
            }
            // Compact mid-storm: in-flight requests keep serving from
            // their pinned generation while the rewrite lands.
            let store = Arc::clone(&store);
            scope.spawn(move || {
                std::thread::sleep(Duration::from_millis(10));
                let summary = store.compact_live().unwrap();
                assert!(summary.generation >= 1, "compaction must advance the generation");
            });
        });
        let m = engine.metrics();
        let total = (clients * requests) as u64;
        assert_eq!(m.submitted, total, "{shards} shard(s)");
        assert_eq!(m.completed, total, "zero non-shed errors under live compaction");
        assert_eq!(m.shed_total(), 0);
        assert!(store.generation() >= 1, "handle reloaded onto the compacted generation");
        // Post-compaction reads come from the new generation, still
        // bit-exact.
        for name in &names {
            let expect = &reference[name];
            let covered = store.meta(name).unwrap().chunk_value_range(0);
            let got = engine.get_chunk(name, 0).unwrap();
            assert_eq!(
                got.as_slice(),
                &expect[covered.start as usize..covered.end as usize]
            );
        }
        drop(engine);
        cleanup(&path);
    }
}

/// Errors inside requests surface through tickets; the engine keeps
/// serving afterwards (no worker death, no hang).
#[test]
fn request_errors_do_not_poison_the_engine() {
    let (path, reference) = build_store("errors", 1, 10_000, 1);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        store,
        ServingConfig {
            workers: 2,
            queue_depth: 32,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        },
    )
    .unwrap();
    assert!(engine.get_tensor("absent").is_err());
    assert!(engine.get_chunk("t0", 9999).is_err());
    assert!(engine.get_range("t0", 9..3).is_err());
    // Still serving, bit-exactly.
    assert_eq!(
        engine.get_tensor("t0").unwrap().as_slice(),
        &reference["t0"][..]
    );
    let m = engine.metrics();
    assert_eq!(m.completed, 4, "error responses count as completed work");
    drop(engine);
    cleanup(&path);
}
