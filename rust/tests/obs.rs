//! Observability integration tests (ISSUE 6): metric invariants (counters
//! monotonic, quantiles ordered), the disabled tracer recording nothing,
//! span-tree well-formedness under a concurrent serving run, request
//! coverage, and the exporters (Chrome trace JSON parses, Prometheus
//! text, JSONL snapshot stream). The attribution layer (ISSUE 8) adds:
//! cross-thread lane-span parenting on the threaded decode, profile
//! folding consistent with the request histogram, and tail exemplars
//! exporting as valid Chrome trace JSON.
//!
//! The span tracer is process-global, and libtest runs `#[test]` fns on
//! parallel threads — every test that enables/drains the tracer holds
//! [`TRACER`] for its whole body so concurrent tests cannot steal each
//! other's spans.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use apack_repro::apack::tablegen::{table_for_tensor, TensorKind};
use apack_repro::apack::{encode_body_v2, BodyV2View};
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::distributions::ValueProfile;
use apack_repro::obs::{self, rates, LatencyHistogram, MetricsRegistry, SnapshotStream, Stage};
use apack_repro::serving::{ServingConfig, ServingEngine};
use apack_repro::store::{StoreHandle, StoreWriter};
use apack_repro::util::json::Json;
use apack_repro::util::Rng64;

/// Global-tracer serialization (see module docs).
static TRACER: Mutex<()> = Mutex::new(());

fn tracer_lock() -> MutexGuard<'static, ()> {
    let guard = TRACER.lock().unwrap_or_else(|e| e.into_inner());
    obs::disable();
    obs::clear();
    obs::drain();
    guard
}

fn tensor_values(n: usize, seed: u64) -> Vec<u32> {
    ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, seed)
}

/// Pack a small single-file store for the serving/reader tests.
fn build_store(
    tag: &str,
    n_tensors: usize,
    n_values: usize,
) -> (PathBuf, HashMap<String, Vec<u32>>) {
    let path = std::env::temp_dir()
        .join(format!("apack_obs_{}_{tag}.apackstore", std::process::id()));
    let policy = PartitionPolicy { substreams: 8, min_per_stream: 256 };
    let tensors: Vec<(String, Vec<u32>)> = (0..n_tensors)
        .map(|i| (format!("t{i}"), tensor_values(n_values, 9100 + i as u64)))
        .collect();
    let mut writer = StoreWriter::create(&path, policy).unwrap();
    for (name, values) in &tensors {
        writer.add_tensor(name, 8, values, TensorKind::Activations).unwrap();
    }
    writer.finish().unwrap();
    (path, tensors.into_iter().collect())
}

fn cleanup(path: &PathBuf) {
    std::fs::remove_file(path).ok();
}

// ---------------------------------------------------------------------------
// Metric invariants.

/// Registry counters only move up, under concurrent writers, and
/// successive snapshots observe non-decreasing values.
#[test]
fn counters_are_monotonic_under_concurrency() {
    let registry = MetricsRegistry::new();
    let c = registry.counter("test.ops");
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let c = Arc::clone(&c);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    c.inc();
                }
            });
        }
        let mut prev = 0u64;
        for _ in 0..200 {
            let now = registry.snapshot().counter("test.ops");
            assert!(now >= prev, "counter went backwards: {now} < {prev}");
            prev = now;
        }
        done.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    assert!(registry.snapshot().counter("test.ops") > 0);
}

/// The shared histogram keeps its quantiles ordered (p50 ≤ p95 ≤ p99 ≤
/// max) on skewed and uniform inputs alike.
#[test]
fn histogram_quantiles_are_ordered() {
    let h = LatencyHistogram::new();
    let mut rng = Rng64::new(0x0B5);
    for _ in 0..5000 {
        // Heavy-tailed: mostly microseconds, occasional milliseconds.
        let ns = if rng.chance(0.95) { 500 + rng.below(20_000) } else { rng.below(5_000_000) };
        h.record(Duration::from_nanos(ns));
    }
    let s = h.snapshot();
    assert_eq!(s.count, 5000);
    assert!(
        s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max,
        "quantiles out of order: {}",
        s.render()
    );
    assert!(s.mean <= s.max);
}

/// `rates` helpers (deduped from eval + writer): sane values, no division
/// by zero.
#[test]
fn rates_helpers_are_sane() {
    assert!((rates::per_sec(1000.0, 1_000_000_000) - 1000.0).abs() < 1e-9);
    assert!((rates::mb_per_s(1_000_000.0, 1_000_000_000) - 1.0).abs() < 1e-9);
    assert!((rates::gb_per_s(1_000_000_000.0, 1_000_000_000) - 1.0).abs() < 1e-9);
    // Zero-duration measurements clamp instead of producing inf/NaN.
    assert!(rates::per_sec(1000.0, 0).is_finite());
}

// ---------------------------------------------------------------------------
// Tracer behavior.

/// With the tracer disabled (the default), instrumented hot paths record
/// nothing — a full pack + read cycle leaves the ring buffers empty.
#[test]
fn disabled_tracer_records_zero_events() {
    let _g = tracer_lock();
    let (path, reference) = build_store("disabled", 2, 8_000);
    let store = StoreHandle::open(&path).unwrap();
    for (name, values) in &reference {
        assert_eq!(&store.get_tensor(name).unwrap(), values);
    }
    drop(store);
    cleanup(&path);
    assert!(!obs::enabled());
    assert_eq!(obs::drain().len(), 0, "disabled tracer must record nothing");
}

/// Concurrent serving with tracing on: the drained span forest is
/// well-formed (every span's parent is another drained span or the root,
/// end ≥ start, one Request span per submitted request, the expected
/// stages present) and direct children cover most of each request's wall
/// clock. The release-build `serve-bench --trace` run in CI holds the
/// stricter ≥95% acceptance bar; a debug-build test box gets headroom.
#[test]
fn concurrent_serve_span_tree_is_well_formed() {
    let _g = tracer_lock();
    let (path, reference) = build_store("serve", 3, 12_000);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 3,
            queue_depth: 256,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        },
    )
    .unwrap();
    let names: Vec<String> = reference.keys().cloned().collect();

    obs::enable();
    let clients = 4usize;
    let requests = 25usize;
    std::thread::scope(|scope| {
        for tid in 0..clients {
            let engine = &engine;
            let reference = &reference;
            let names = &names;
            scope.spawn(move || {
                let mut rng = Rng64::new(0x0B5E + tid as u64);
                for _ in 0..requests {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    let n = reference[name].len() as u64;
                    let lo = rng.below(n);
                    let hi = (lo + 1 + rng.below(2048)).min(n);
                    let got = engine.get_range(name, lo..hi).unwrap();
                    assert_eq!(got[..], reference[name][lo as usize..hi as usize]);
                }
            });
        }
    });
    // One full-tensor read: spans several chunks, so the multi-chunk
    // assembly (CopyOut) path is exercised deterministically.
    assert_eq!(&*engine.get_tensor(&names[0]).unwrap(), &reference[&names[0]]);
    let snap = engine.registry_snapshot();
    drop(engine);
    drop(store);
    cleanup(&path);
    obs::disable();
    let events = obs::drain();

    // Registry view agrees with the workload (clients × requests plus the
    // full-tensor read above).
    let total = (clients * requests) as u64 + 1;
    assert_eq!(snap.counter("serving.submitted"), total);
    assert_eq!(snap.counter("serving.completed"), total);
    assert_eq!(snap.hist("serving.latency_ns").count, total);

    // Forest well-formedness.
    let ids: std::collections::BTreeSet<u64> = events.iter().map(|e| e.id).collect();
    assert_eq!(ids.len(), events.len(), "span ids must be unique");
    for e in &events {
        assert!(e.id != 0, "recorded span must have a nonzero id");
        assert!(e.end_ns >= e.start_ns, "span {} ends before it starts", e.id);
        assert!(
            e.parent == 0 || ids.contains(&e.parent),
            "span {} has dangling parent {}",
            e.id,
            e.parent
        );
    }
    let n_stage = |s: Stage| events.iter().filter(|e| e.stage == s).count() as u64;
    assert_eq!(n_stage(Stage::Request), total, "one Request span per request");
    assert_eq!(n_stage(Stage::Admit), total);
    assert_eq!(n_stage(Stage::QueueWait), total);
    assert_eq!(n_stage(Stage::Execute), total);
    assert!(n_stage(Stage::Decode) > 0, "chunk decodes must be traced");
    assert!(n_stage(Stage::ChunkIo) > 0, "chunk reads must be traced");
    assert!(n_stage(Stage::CopyOut) > 0, "range assembly must be traced");
    assert_eq!(obs::dropped(), 0, "ring buffers must not overflow this workload");

    // Every non-root stage hangs under the right parent stage.
    let stage_of: std::collections::BTreeMap<u64, Stage> =
        events.iter().map(|e| (e.id, e.stage)).collect();
    for e in &events {
        if matches!(e.stage, Stage::Admit | Stage::QueueWait | Stage::Execute) {
            assert_eq!(stage_of[&e.parent], Stage::Request, "{:?} not under Request", e.stage);
        }
    }

    // The store defaults to v2 chunk bodies (16 lanes), so the serial
    // lane decode fans out: every DecodeLanes span hangs under a Decode
    // span (ISSUE 8 extends this forest test to the v2 lane path).
    assert!(n_stage(Stage::DecodeLanes) > 0, "v2 lane fan-out must be traced");
    for e in events.iter().filter(|e| e.stage == Stage::DecodeLanes) {
        assert_eq!(stage_of[&e.parent], Stage::Decode, "DecodeLanes not under Decode");
    }

    let cov = obs::request_coverage(&events).expect("request spans present");
    assert!(cov >= 0.90, "median request coverage {cov:.3} below the 0.90 test floor");

    // Attribution profile (ISSUE 8) stays consistent with the request
    // histogram: the folded `request` root path counts exactly the
    // histogram's requests, and the request-rooted self times tile the
    // requests' wall-clock (no stage is attributed more than once).
    let profile = obs::Profile::from_events(&events);
    let req = profile.get("request").expect("request path folded");
    assert_eq!(req.count, total, "attribution request count != histogram count");
    let request_wall: u64 = events
        .iter()
        .filter(|e| e.stage == Stage::Request)
        .map(|e| e.duration_ns())
        .sum();
    let folded: u64 = profile
        .iter()
        .filter(|(p, _)| *p == "request" || p.starts_with("request;"))
        .map(|(_, s)| s.self_ns)
        .sum();
    assert!(
        folded <= request_wall,
        "request-rooted self times ({folded} ns) exceed request wall-clock \
         ({request_wall} ns)"
    );
    assert!(
        folded * 10 >= request_wall * 8,
        "request-rooted self times attribute only {folded} of {request_wall} ns"
    );
}

/// The threaded lane decode begins its fan-out span on the calling thread
/// and threads the id to the workers ([`obs::with_parent`]), so every
/// worker-group `Decode` span parents under `DecodeLanes` instead of
/// rooting at 0 (the ISSUE 8 cross-thread parenting fix). Since ISSUE 9
/// workers own contiguous lane *groups* (one span per group, lanes
/// decoded round-major inside the SIMD/scalar kernel) and the fan-out
/// span carries the active kernel as an attribution tag.
#[test]
fn threaded_lane_decode_parents_worker_spans_under_fanout() {
    use apack_repro::apack::DecodeKernel;
    let _g = tracer_lock();
    let values = tensor_values(40_000, 77);
    let table = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let body = encode_body_v2(&table, &values, 16).unwrap();
    let view = BodyV2View::parse(&body).unwrap();

    obs::enable();
    let mut out = vec![0u32; values.len()];
    view.decode_into_threaded(&table, &mut out, 4).unwrap();
    obs::disable();
    let events = obs::drain();
    assert_eq!(out, values);

    let fans: Vec<_> = events.iter().filter(|e| e.stage == Stage::DecodeLanes).collect();
    assert_eq!(fans.len(), 1, "one fan-out span per threaded decode");
    let fan = fans[0];
    assert_eq!(fan.count, 16, "fan-out span carries the lane count");
    let label = DecodeKernel::auto().active_label();
    assert_eq!(fan.tag, label, "fan-out span carries the active kernel tag");
    // 16 lanes over 4 worker threads → 4 contiguous groups of 4 lanes,
    // one Decode span per group covering that group's values.
    let groups: Vec<_> = events.iter().filter(|e| e.stage == Stage::Decode).collect();
    assert_eq!(groups.len(), 4, "one Decode span per worker lane-group");
    assert_eq!(
        groups.iter().map(|e| e.count).sum::<u64>(),
        values.len() as u64,
        "group spans cover every value exactly once"
    );
    let tids: std::collections::BTreeSet<u64> = groups.iter().map(|e| e.tid).collect();
    assert!(tids.len() > 1, "group decodes must come from several worker threads");
    for g in &groups {
        assert_eq!(g.parent, fan.id, "worker-group Decode must hang under DecodeLanes");
        assert_ne!(g.tid, fan.tid, "worker spans record on worker threads");
    }
    // The folded profile sees the full tagged path, so lane time
    // attributes under the fan-out (split by kernel) instead of an
    // orphan `decode` root.
    let profile = obs::Profile::from_events(&events);
    let path = format!("decode_lanes[{label}];decode[{label}]");
    assert!(profile.get(&path).is_some(), "tagged lane path {path:?} must fold");
    assert!(profile.get("decode").is_none(), "no orphan lane roots remain");
}

/// End-to-end tail sampling (ISSUE 8): a traced serving run joined with
/// the engine's outcome ring retains slow-tail exemplars whose span trees
/// export as valid Chrome trace JSON.
#[test]
fn tail_exemplars_export_valid_chrome_trace() {
    let _g = tracer_lock();
    let (path, reference) = build_store("exemplar", 2, 10_000);
    let store = Arc::new(StoreHandle::open(&path).unwrap());
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig { workers: 2, ..ServingConfig::default() },
    )
    .unwrap();
    let names: Vec<String> = reference.keys().cloned().collect();

    obs::enable();
    let mut rng = Rng64::new(0xE4E);
    let requests = 60usize;
    for i in 0..requests {
        let name = &names[i % names.len()];
        let n = reference[name].len() as u64;
        if i % 10 == 0 {
            // Induced slow requests: full-tensor reads decode every chunk,
            // so the tail has real structure to retain.
            assert_eq!(&*engine.get_tensor(name).unwrap(), &reference[name]);
        } else {
            let lo = rng.below(n - 64);
            engine.get_range(name, lo..lo + 64).unwrap();
        }
    }
    let records = engine.request_outcomes();
    drop(engine);
    drop(store);
    cleanup(&path);
    obs::disable();
    let events = obs::drain();

    assert_eq!(records.len(), requests, "every traced request lands in the outcome ring");
    let ring = obs::collect_exemplars(&events, &records, 8);
    assert!(!ring.is_empty(), "a tail exemplar must be retained");
    let exemplars = ring.exemplars();
    assert!(exemplars.len() <= 8);
    for e in &exemplars {
        assert!(!e.events.is_empty(), "exemplar without a span tree");
        assert!(
            e.events.iter().any(|ev| ev.id == e.span_id),
            "exemplar tree must contain its request root"
        );
    }
    // Slowest-first ordering (all outcomes are Ok here).
    for w in exemplars.windows(2) {
        assert!(w[0].latency_ns >= w[1].latency_ns);
    }

    let out = std::env::temp_dir()
        .join(format!("apack_obs_exemplars_{}.json", std::process::id()));
    ring.write_chrome_trace(&out).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!arr.is_empty(), "exemplar Chrome trace holds events");
    std::fs::remove_file(&out).ok();
}

// ---------------------------------------------------------------------------
// Exporters.

/// End-to-end exporter check over real spans and a real registry: the
/// Chrome trace document parses and holds every span; Prometheus text and
/// the JSONL stream carry the registry contents.
#[test]
fn exporters_round_trip_real_telemetry() {
    let _g = tracer_lock();
    obs::enable();
    {
        let mut outer = obs::span_n(Stage::Encode, 64);
        outer.set_count(128);
        let _inner = obs::span(Stage::ChunkIo);
    }
    obs::disable();
    let events = obs::drain();
    assert_eq!(events.len(), 2);

    let trace_path = std::env::temp_dir()
        .join(format!("apack_obs_trace_{}.json", std::process::id()));
    obs::write_chrome_trace(&trace_path, &events).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(arr.len(), events.len());
    std::fs::remove_file(&trace_path).ok();

    let registry = MetricsRegistry::new();
    registry.counter("demo.ops").add(42);
    registry.gauge("demo.depth").set(3);
    registry.histogram("demo.latency_ns").record(Duration::from_micros(10));
    let text = obs::prometheus_text(&registry.snapshot());
    assert!(text.contains("demo_ops 42"));
    assert!(text.contains("# TYPE demo_depth gauge"));
    assert!(text.contains("demo_latency_ns_count 1"));

    // JSONL stream: every line parses, `seq` increases, final line flushed
    // on drop.
    let jsonl_path = std::env::temp_dir()
        .join(format!("apack_obs_snap_{}.jsonl", std::process::id()));
    {
        let reg = Arc::new(registry);
        let src = Arc::clone(&reg);
        let stream = SnapshotStream::start(&jsonl_path, Duration::from_millis(5), move || {
            src.snapshot()
        })
        .unwrap();
        reg.counter("demo.ops").add(8);
        std::thread::sleep(Duration::from_millis(25));
        drop(stream);
    }
    let body = std::fs::read_to_string(&jsonl_path).unwrap();
    let lines: Vec<&str> = body.lines().collect();
    assert!(lines.len() >= 2, "expected several snapshot lines, got {}", lines.len());
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), i);
    }
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(
        last.get("counters").unwrap().get("demo.ops").unwrap().as_usize().unwrap(),
        50
    );
    std::fs::remove_file(&jsonl_path).ok();
}
